//! Online multi-tenant pricing scheduler: continuous job arrivals,
//! epoch-based incremental re-optimisation, SLO tracking.
//!
//! The paper prices one batch of 128 options once. Its own pitch — FPGAs
//! "available by the hour" as IaaS — implies a *service*: clients keep
//! submitting pricing jobs, each with a service-level objective (a deadline
//! in cluster-virtual seconds or a dollar budget), and the task→platform
//! allocation must stay Pareto-optimal as the mix of in-flight work
//! changes. [`OnlineScheduler`] is that layer:
//!
//! 1. **Admit** — arrivals queue; at each epoch boundary up to
//!    `max_in_flight` jobs are admitted and batched into one combined
//!    workload of their *remaining* work.
//! 2. **Plan** — the batch is partitioned by an ordinary [`Partitioner`]
//!    over models rebuilt from the current per-platform throughput
//!    estimates. The previous epoch's incumbent allocation is reused
//!    verbatim while the job set is unchanged and the models have drifted
//!    less than `resolve_drift` (the same quantize-and-reuse discipline as
//!    the session solution cache); otherwise the solver runs again.
//!    Deadline jobs buy speed (tight slack forces the unconstrained
//!    minimum-makespan solve); an all-budget batch is solved under the sum
//!    of remaining budgets.
//! 3. **Execute one epoch** — [`execute_epoch`] runs the allocation until
//!    lane clocks cross `epoch_secs`; still-queued chunks are deferred, so
//!    a re-plan at the boundary effectively preempts and re-homes them
//!    under the refreshed allocation. Per-task path-counter cursors keep
//!    epochs Monte-Carlo-disjoint.
//! 4. **Observe** — measured chunk latencies feed the
//!    [`OnlineLatencyFit`] re-fit (window `refit_window`), so the next
//!    epoch solves against refreshed models; each epoch's mean relative
//!    model error is recorded in [`EpochRecord`].
//!
//! Jobs complete when every task has simulated its required paths; prices
//! merge the per-epoch payoff statistics in epoch order (deterministic).
//! [`JobStatus::slo_met`] reports whether the deadline (virtual time from
//! submission) or budget (attributed cost) held.
//!
//! The serve protocol's `submit`/`jobs`/`cancel` ops and the CLI `jobs`
//! command drive this through
//! [`TradeoffSession::submit_job`](crate::api::TradeoffSession::submit_job):
//!
//! ```no_run
//! use cloudshapes::api::SessionBuilder;
//! use cloudshapes::coordinator::scheduler::{JobSpec, SchedulerConfig, Slo};
//!
//! let session = SessionBuilder::quick()
//!     .partitioner("heuristic")
//!     .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
//!     .build()?;
//! let job = JobSpec::generate(None, 2, 0.05, 7, Slo::Deadline(3600.0))?;
//! let id = session.submit_job(job)?;
//! while let Some(status) = session.job_status(id)? {
//!     if status.state.is_terminal() {
//!         println!("job {id}: {} (SLO met: {:?})", status.state.name(), status.slo_met);
//!         break;
//!     }
//! }
//! # Ok::<(), cloudshapes::api::CloudshapesError>(())
//! ```

use std::collections::{BTreeMap, HashMap};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::executor::{execute_epoch, EpochCtx, ExecEvent, ExecutorConfig};
use crate::coordinator::objectives::ModelSet;
use crate::coordinator::partitioner::Partitioner;
use crate::coordinator::Allocation;
use crate::models::forecast::{Autoscaler, ForecastConfig, PlatformEcon};
use crate::models::online::{OnlineLatencyFit, PlatformPrior};
use crate::models::{CostModel, LatencyModel};
use crate::obs::{Counter, Gauge, Histogram, MetricsRegistry};
use crate::platforms::Cluster;
use crate::pricing::mc::{combine, PayoffStats, PriceEstimate};
use crate::workload::{try_generate, GeneratorConfig, OptionTask, Payoff, Workload};

/// `[scheduler]` configuration keys (see `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// Whether the session accepts jobs at all (`serve --scheduler` or
    /// `[scheduler] enabled = true`). Disabled sessions answer job ops with
    /// a typed config error instead of silently spawning a thread.
    pub enabled: bool,
    /// Cluster-virtual seconds per scheduling epoch — the re-plan cadence.
    pub epoch_secs: f64,
    /// Jobs optimised concurrently; arrivals beyond this wait queued.
    pub max_in_flight: usize,
    /// Observed chunk-latency samples kept per (platform, payoff family)
    /// for the incremental re-fit; 0 disables re-fitting.
    pub refit_window: usize,
    /// Re-fit latency models per payoff family (fallback chain: family
    /// window → platform-pooled → prior). `false` is the ablation switch
    /// back to the single pooled line per platform.
    pub family_refit: bool,
    /// Relative throughput drift (vs the models of the last solve) that
    /// forces a re-solve at the next epoch boundary.
    pub resolve_drift: f64,
    /// Incremental re-plan quality gate: a delta-admitted (or memoized)
    /// allocation is accepted only while its predicted makespan stays
    /// within this factor of the batch's fluid lower bound (plus one worst
    /// setup); past that the cheap path is mispricing the batch and the
    /// full solve runs. Must be >= 1.
    pub repair_quality: f64,
    /// Entries kept in the memoized plan cache, keyed on the quantised
    /// remaining-work signature (0 disables memoization).
    pub plan_memo: usize,
    /// Predictive autoscaling — arrival forecasting, pre-rent and drain
    /// (`[forecast]`, see `docs/CONFIG.md`).
    pub forecast: ForecastConfig,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            enabled: false,
            epoch_secs: 600.0,
            max_in_flight: 8,
            refit_window: 64,
            family_refit: true,
            resolve_drift: 0.15,
            repair_quality: 2.0,
            plan_memo: 256,
            forecast: ForecastConfig::default(),
        }
    }
}

impl SchedulerConfig {
    /// Validate the knobs (the config parser and [`OnlineScheduler::start`]
    /// both route through this).
    pub fn validate(&self) -> Result<()> {
        if !(self.epoch_secs > 0.0 && self.epoch_secs.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "scheduler.epoch_secs must be positive and finite, got {}",
                self.epoch_secs
            )));
        }
        if self.max_in_flight == 0 {
            return Err(CloudshapesError::config("scheduler.max_in_flight must be >= 1"));
        }
        if !(self.resolve_drift > 0.0 && self.resolve_drift.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "scheduler.resolve_drift must be positive, got {}",
                self.resolve_drift
            )));
        }
        if !(self.repair_quality >= 1.0 && self.repair_quality.is_finite()) {
            return Err(CloudshapesError::config(format!(
                "scheduler.repair_quality must be >= 1 and finite, got {}",
                self.repair_quality
            )));
        }
        self.forecast.validate()?;
        Ok(())
    }
}

/// A job's service-level objective.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Slo {
    /// Finish within this many cluster-virtual seconds of submission.
    Deadline(f64),
    /// Finish within this attributed spend, $.
    Budget(f64),
}

impl Slo {
    fn validate(&self) -> Result<()> {
        let (name, v) = match self {
            Slo::Deadline(v) => ("deadline", *v),
            Slo::Budget(v) => ("budget", *v),
        };
        if !(v > 0.0 && v.is_finite()) {
            return Err(CloudshapesError::workload(format!(
                "job {name} must be positive and finite, got {v}"
            )));
        }
        Ok(())
    }
}

/// A pricing job: tasks to price plus the SLO to price them under.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub tasks: Vec<OptionTask>,
    pub slo: Slo,
}

impl JobSpec {
    /// Most tasks one job may carry (also the task-id stride that keeps
    /// every job's RNG streams disjoint from every other job's).
    pub const MAX_TASKS: usize = 256;

    /// Validate and build a job from explicit tasks.
    pub fn new(tasks: Vec<OptionTask>, slo: Slo) -> Result<JobSpec> {
        if tasks.is_empty() {
            return Err(CloudshapesError::workload("job has no tasks"));
        }
        if tasks.len() > JobSpec::MAX_TASKS {
            return Err(CloudshapesError::workload(format!(
                "job has {} tasks (max {})",
                tasks.len(),
                JobSpec::MAX_TASKS
            )));
        }
        for t in &tasks {
            t.validate()?;
        }
        slo.validate()?;
        Ok(JobSpec { tasks, slo })
    }

    /// Generate a job's tasks Kaiserslautern-style: `n_tasks` options at
    /// `accuracy`, drawn from `seed`, restricted to one payoff family when
    /// `payoff` is given (the serve `submit` op's path).
    pub fn generate(
        payoff: Option<Payoff>,
        n_tasks: usize,
        accuracy: f64,
        seed: u64,
        slo: Slo,
    ) -> Result<JobSpec> {
        let payoff_mix = match payoff {
            None => GeneratorConfig::default().payoff_mix,
            Some(p) => p.one_hot_mix(),
        };
        let cfg = GeneratorConfig {
            n_tasks,
            seed,
            accuracy,
            payoff_mix,
            step_choices: vec![64],
            ..GeneratorConfig::default()
        };
        let workload = try_generate(&cfg)?;
        JobSpec::new(workload.tasks, slo)
    }
}

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, PartialEq)]
pub enum JobState {
    /// Waiting for an in-flight slot.
    Queued,
    /// Admitted: participating in epochs.
    Running,
    /// Every task priced.
    Done,
    /// Cancelled by the client; capacity returned to the queue.
    Cancelled,
    /// The scheduler gave up on it; the message says why.
    Failed(String),
}

impl JobState {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobState::Queued | JobState::Running)
    }

    /// Stable lowercase tag (the wire `status` field).
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Cancelled => "cancelled",
            JobState::Failed(_) => "failed",
        }
    }
}

/// Snapshot of one job (the serve `jobs` op's payload).
#[derive(Debug, Clone)]
pub struct JobStatus {
    pub id: u64,
    pub state: JobState,
    pub slo: Slo,
    pub tasks_total: usize,
    pub sims_total: u64,
    pub sims_done: u64,
    /// Epochs this job participated in.
    pub epochs: usize,
    /// Cost attributed to this job so far (epoch cost split by executed
    /// work), $.
    pub cost: f64,
    /// Cluster-virtual clock at submission.
    pub arrival_s: f64,
    /// Cluster-virtual clock when the job reached a terminal state.
    pub finished_s: Option<f64>,
    /// Conservative predicted completion (virtual): the latest epoch
    /// plan's full-remaining-work makespan from the clock at that plan.
    pub predicted_finish_s: Option<f64>,
    /// Whether the SLO held, known once terminal (`None` while running;
    /// cancelled/failed jobs report `Some(false)`).
    pub slo_met: Option<bool>,
    /// Per-task discounted price estimates (populated as tasks finish).
    pub prices: Vec<Option<PriceEstimate>>,
}

/// One epoch's planning/execution record (diagnostics + tests).
#[derive(Debug, Clone)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Jobs and tasks in this epoch's batch.
    pub jobs: usize,
    pub tasks: usize,
    /// Whether the solver ran (false = the warm incumbent was reused).
    pub resolved: bool,
    /// Budget the solve ran under (None = unconstrained).
    pub budget: Option<f64>,
    /// Predicted full-remaining makespan of the *previous* incumbent under
    /// this epoch's refreshed models (present whenever one existed).
    pub warm_makespan_s: Option<f64>,
    /// Predicted full-remaining makespan of the chosen allocation.
    pub predicted_makespan_s: f64,
    /// Measured virtual seconds this epoch actually ran.
    pub measured_epoch_s: f64,
    pub epoch_cost: f64,
    /// Mean relative |predicted − measured| over this epoch's chunks.
    pub model_error: f64,
}

/// Aggregate scheduler counters (the serve `ping` op reports a summary).
#[derive(Debug, Clone, Default)]
pub struct SchedulerStats {
    pub submitted: u64,
    pub completed: u64,
    pub cancelled: u64,
    pub failed: u64,
    pub epochs: usize,
    /// Epochs that ran the solver.
    pub resolves: usize,
    /// Epochs that reused the warm incumbent.
    pub warm_reuses: usize,
    /// Epochs re-planned incrementally: new arrivals delta-admitted into
    /// the incumbent instead of a cold re-solve.
    pub replans_incremental: usize,
    /// Epochs that fell back to a full solve despite holding an incumbent
    /// (drift, budget change, or repair-quality failure) — the cold path a
    /// storm would otherwise take every epoch. A subset of `resolves`.
    pub replans_full: usize,
    /// Epochs planned straight from the memoized signature cache.
    pub memo_hits: usize,
    /// Wall-clock seconds spent in incremental planning / in full solves
    /// (the storm bench's speedup numerator and denominator).
    pub plan_secs_incremental: f64,
    pub plan_secs_full: f64,
    /// Instances the autoscaler held rented at the last epoch boundary.
    pub rented_instances: usize,
    /// Holding cost of rented-but-idle instances accumulated so far, $ —
    /// billed to the operator, never attributed to a job's budget.
    pub idle_cost: f64,
    /// Arrival forecaster relative-error EWMA (None until the first
    /// scored forecast).
    pub forecast_error: Option<f64>,
    /// Model error of the first / most recent epoch — the re-fit
    /// tightening metric.
    pub first_model_error: Option<f64>,
    pub last_model_error: Option<f64>,
    /// Recent epoch records (oldest evicted past a cap; the first/last
    /// error fields above survive eviction).
    pub records: Vec<EpochRecord>,
}

/// Records kept in [`SchedulerStats::records`].
const MAX_EPOCH_RECORDS: usize = 1024;

/// Upper bound on tracked jobs (queued/running ones are never evicted). A
/// continuously-admitting service must not grow without bound: past the
/// cap, the oldest *terminal* job is evicted on submit; with every tracked
/// job still live, new submits are refused — the same backpressure
/// discipline as the session's run registry.
const MAX_TRACKED_JOBS: usize = 1024;

/// Give up on jobs after this many consecutive epochs of zero progress
/// (every lane failing/preempted): keeps a doomed cluster from spinning.
const MAX_STALLED_EPOCHS: usize = 3;

/// Per-task state inside a job.
#[derive(Debug, Clone)]
struct JobTask {
    /// The task with its id remapped into the job's private id range
    /// (stable across epochs: it keys the RNG streams).
    task: OptionTask,
    /// Simulations still needed.
    remaining: u64,
    /// Next fresh path-counter base; advances by the *requested* sims each
    /// epoch so ranges never overlap even when chunks fail or defer.
    cursor: u64,
    /// Payoff statistics accumulated across epochs.
    stats: PayoffStats,
}

#[derive(Debug)]
struct Job {
    id: u64,
    state: JobState,
    slo: Slo,
    tasks: Vec<JobTask>,
    sims_total: u64,
    sims_done: u64,
    epochs: usize,
    cost: f64,
    arrival_s: f64,
    finished_s: Option<f64>,
    predicted_finish_s: Option<f64>,
    slo_met: Option<bool>,
}

impl Job {
    fn status(&self) -> JobStatus {
        JobStatus {
            id: self.id,
            state: self.state.clone(),
            slo: self.slo,
            tasks_total: self.tasks.len(),
            sims_total: self.sims_total,
            sims_done: self.sims_done,
            epochs: self.epochs,
            cost: self.cost,
            arrival_s: self.arrival_s,
            finished_s: self.finished_s,
            predicted_finish_s: self.predicted_finish_s,
            slo_met: self.slo_met,
            prices: self
                .tasks
                .iter()
                .map(|t| {
                    if t.remaining == 0 && t.stats.n > 0 {
                        Some(combine(&t.stats, t.task.discount()))
                    } else {
                        None
                    }
                })
                .collect(),
        }
    }
}

struct SchedState {
    jobs: BTreeMap<u64, Job>,
    next_id: u64,
    /// Cluster-virtual clock: the sum of epoch makespans so far.
    clock: f64,
    shutdown: bool,
    stats: SchedulerStats,
    /// Work (flops) submitted since the last epoch boundary — drained by
    /// the epoch thread into the arrival forecaster.
    arrived_flops: f64,
    /// Set when the partitioner factory failed on the epoch thread.
    fatal: Option<CloudshapesError>,
}

/// Registry handles the scheduler updates at the very same sites as its own
/// [`SchedulerStats`] fields (under the same lock), so the serve `ping` op —
/// which reads these registry cells — and [`OnlineScheduler::stats`] can
/// never disagree. Handle-addressed metrics count even when `[obs]` is
/// disabled, mirroring the session cache-stats discipline; only the
/// name-addressed per-chunk observations respect the enabled flag.
struct SchedMetrics {
    submitted: Arc<Counter>,
    completed: Arc<Counter>,
    cancelled: Arc<Counter>,
    failed: Arc<Counter>,
    epochs: Arc<Counter>,
    resolves: Arc<Counter>,
    warm_reuses: Arc<Counter>,
    replans_incremental: Arc<Counter>,
    replans_full: Arc<Counter>,
    memo_hits: Arc<Counter>,
    /// Submits refused with the registry live-full — the scheduler's lane
    /// of the serve plane's `serve_shed_total{reason=}` family, so storms
    /// shed visibly.
    shed_jobs_full: Arc<Counter>,
    rented_instances: Arc<Gauge>,
    forecast_error: Arc<Gauge>,
    model_error_first: Arc<Gauge>,
    model_error_last: Arc<Gauge>,
    epoch_model_error: Arc<Histogram>,
}

impl SchedMetrics {
    fn new(reg: &MetricsRegistry) -> SchedMetrics {
        SchedMetrics {
            submitted: reg.counter("scheduler_submitted_total", ""),
            completed: reg.counter("scheduler_completed_total", ""),
            cancelled: reg.counter("scheduler_cancelled_total", ""),
            failed: reg.counter("scheduler_failed_total", ""),
            epochs: reg.counter("scheduler_epochs_total", ""),
            resolves: reg.counter("scheduler_resolves_total", ""),
            warm_reuses: reg.counter("scheduler_warm_reuses_total", ""),
            replans_incremental: reg.counter("scheduler_replans_incremental_total", ""),
            replans_full: reg.counter("scheduler_replans_full_total", ""),
            memo_hits: reg.counter("scheduler_plan_memo_hits_total", ""),
            shed_jobs_full: reg.counter("serve_shed_total", "reason=jobs_full"),
            rented_instances: reg.gauge("scheduler_rented_instances", ""),
            forecast_error: reg.gauge("scheduler_forecast_error", ""),
            model_error_first: reg.gauge("scheduler_model_error", "stage=first"),
            model_error_last: reg.gauge("scheduler_model_error", "stage=last"),
            epoch_model_error: reg.histogram("scheduler_epoch_model_error", ""),
        }
    }
}

struct Inner {
    cluster: Cluster,
    exec: ExecutorConfig,
    cfg: SchedulerConfig,
    priors: Vec<PlatformPrior>,
    /// Counter/gauge handles into `reg` (see [`SchedMetrics`]).
    metrics: Option<SchedMetrics>,
    /// The owning session's registry, for per-chunk latency/model-error
    /// observations on the epoch thread.
    reg: Option<Arc<MetricsRegistry>>,
    state: Mutex<SchedState>,
    wake: Condvar,
}

/// The online scheduler: submit jobs, poll their status, cancel them. One
/// background thread runs the epoch loop; dropping the handle (or calling
/// [`shutdown`](Self::shutdown)) stops it at the next boundary.
pub struct OnlineScheduler {
    inner: Arc<Inner>,
}

impl Drop for OnlineScheduler {
    fn drop(&mut self) {
        self.shutdown();
    }
}

impl OnlineScheduler {
    /// Start the epoch thread over `cluster`. `priors` seed the per-platform
    /// throughput estimates (one per platform, usually from benchmark
    /// fits); `make_partitioner` builds the per-epoch solver on the
    /// scheduler thread.
    pub fn start<F>(
        cluster: Cluster,
        priors: Vec<PlatformPrior>,
        exec: ExecutorConfig,
        cfg: SchedulerConfig,
        make_partitioner: F,
    ) -> Result<OnlineScheduler>
    where
        F: FnOnce() -> Result<Box<dyn Partitioner>> + Send + 'static,
    {
        Self::start_instrumented(cluster, priors, exec, cfg, None, make_partitioner)
    }

    /// As [`start`](Self::start), additionally recording scheduler counters,
    /// model-error gauges and per-chunk observations into `registry` (the
    /// owning session's) — the path
    /// [`TradeoffSession`](crate::api::TradeoffSession) takes.
    pub fn start_instrumented<F>(
        cluster: Cluster,
        priors: Vec<PlatformPrior>,
        exec: ExecutorConfig,
        cfg: SchedulerConfig,
        registry: Option<Arc<MetricsRegistry>>,
        make_partitioner: F,
    ) -> Result<OnlineScheduler>
    where
        F: FnOnce() -> Result<Box<dyn Partitioner>> + Send + 'static,
    {
        cfg.validate()?;
        if cluster.is_empty() {
            return Err(CloudshapesError::config("scheduler needs a non-empty cluster"));
        }
        if priors.len() != cluster.len() {
            return Err(CloudshapesError::config(format!(
                "scheduler has {} platform priors for {} platforms",
                priors.len(),
                cluster.len()
            )));
        }
        let inner = Arc::new(Inner {
            cluster,
            exec,
            cfg,
            priors,
            metrics: registry.as_deref().map(SchedMetrics::new),
            reg: registry,
            state: Mutex::new(SchedState {
                jobs: BTreeMap::new(),
                next_id: 1,
                clock: 0.0,
                shutdown: false,
                stats: SchedulerStats::default(),
                arrived_flops: 0.0,
                fatal: None,
            }),
            wake: Condvar::new(),
        });
        let thread_inner = Arc::clone(&inner);
        std::thread::Builder::new()
            .name("cloudshapes-scheduler".to_string())
            .spawn(move || epoch_loop(thread_inner, make_partitioner))
            .map_err(|e| {
                CloudshapesError::runtime(format!("spawning scheduler thread: {e}"))
            })?;
        Ok(OnlineScheduler { inner })
    }

    /// Submit a job; returns its id. The job starts `Queued` and is
    /// admitted at the next epoch boundary with a free in-flight slot.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        // Re-validate: specs can be hand-built.
        let spec = JobSpec::new(spec.tasks, spec.slo)?;
        let mut st = self.inner.state.lock().unwrap();
        if st.shutdown {
            return Err(CloudshapesError::runtime("scheduler is shut down"));
        }
        if let Some(e) = &st.fatal {
            return Err(e.clone());
        }
        if st.jobs.len() >= MAX_TRACKED_JOBS {
            // Evict the oldest finished job (ids are monotone); with
            // nothing terminal the cap is a hard admission limit.
            let victim = st
                .jobs
                .iter()
                .filter(|(_, j)| j.state.is_terminal())
                .map(|(id, _)| *id)
                .min();
            match victim {
                Some(v) => {
                    st.jobs.remove(&v);
                }
                None => {
                    // Shed, typed and counted: storms hitting the registry
                    // cap must be visible (serve_shed_total) and
                    // distinguishable from real failures (Overload).
                    if let Some(m) = &self.inner.metrics {
                        m.shed_jobs_full.inc();
                    }
                    return Err(CloudshapesError::overload(format!(
                        "job registry live-full ({MAX_TRACKED_JOBS} jobs queued or \
                         running): wait for completions or cancel before submitting \
                         more"
                    )));
                }
            }
        }
        let id = st.next_id;
        st.next_id += 1;
        let tasks: Vec<JobTask> = spec
            .tasks
            .into_iter()
            .enumerate()
            .map(|(k, mut task)| {
                // Remap into the job's private id range so RNG streams never
                // collide across tenants (ids key the counter-based RNG).
                task.id = (id as usize) * JobSpec::MAX_TASKS + k;
                JobTask {
                    remaining: task.n_sims,
                    cursor: 0,
                    stats: PayoffStats::default(),
                    task,
                }
            })
            .collect();
        let sims_total = tasks.iter().map(|t| t.task.n_sims).sum();
        st.arrived_flops += tasks
            .iter()
            .map(|t| t.task.n_sims as f64 * t.task.flops_per_path())
            .sum::<f64>();
        let arrival_s = st.clock;
        st.jobs.insert(
            id,
            Job {
                id,
                state: JobState::Queued,
                slo: spec.slo,
                tasks,
                sims_total,
                sims_done: 0,
                epochs: 0,
                cost: 0.0,
                arrival_s,
                finished_s: None,
                predicted_finish_s: None,
                slo_met: None,
            },
        );
        st.stats.submitted += 1;
        if let Some(m) = &self.inner.metrics {
            m.submitted.inc();
        }
        drop(st);
        self.inner.wake.notify_all();
        Ok(id)
    }

    /// Cancel a job: `Some(true)` if it transitioned to `Cancelled` (its
    /// remaining work is dropped at the next boundary and the in-flight
    /// slot returns to the queue), `Some(false)` if it was already
    /// terminal, `None` for unknown ids.
    pub fn cancel(&self, id: u64) -> Option<bool> {
        let mut st = self.inner.state.lock().unwrap();
        let clock = st.clock;
        let job = st.jobs.get_mut(&id)?;
        if job.state.is_terminal() {
            return Some(false);
        }
        job.state = JobState::Cancelled;
        job.finished_s = Some(clock);
        job.slo_met = Some(false);
        st.stats.cancelled += 1;
        if let Some(m) = &self.inner.metrics {
            m.cancelled.inc();
        }
        drop(st);
        self.inner.wake.notify_all();
        Some(true)
    }

    /// Snapshot one job.
    pub fn job_status(&self, id: u64) -> Option<JobStatus> {
        self.inner.state.lock().unwrap().jobs.get(&id).map(Job::status)
    }

    /// Snapshot every tracked job, in submission order.
    pub fn jobs(&self) -> Vec<JobStatus> {
        self.inner.state.lock().unwrap().jobs.values().map(Job::status).collect()
    }

    /// Aggregate counters and recent epoch records (clones the full record
    /// ring — use [`counters`](Self::counters) on hot paths).
    pub fn stats(&self) -> SchedulerStats {
        self.inner.state.lock().unwrap().stats.clone()
    }

    /// The counters alone, with the epoch-record ring left empty — what
    /// liveness probes (the serve `ping` op) need, without cloning up to
    /// 1024 records under the scheduler lock per call.
    pub fn counters(&self) -> SchedulerStats {
        let st = self.inner.state.lock().unwrap();
        let s = &st.stats;
        SchedulerStats {
            submitted: s.submitted,
            completed: s.completed,
            cancelled: s.cancelled,
            failed: s.failed,
            epochs: s.epochs,
            resolves: s.resolves,
            warm_reuses: s.warm_reuses,
            replans_incremental: s.replans_incremental,
            replans_full: s.replans_full,
            memo_hits: s.memo_hits,
            plan_secs_incremental: s.plan_secs_incremental,
            plan_secs_full: s.plan_secs_full,
            rented_instances: s.rented_instances,
            idle_cost: s.idle_cost,
            forecast_error: s.forecast_error,
            first_model_error: s.first_model_error,
            last_model_error: s.last_model_error,
            records: Vec::new(),
        }
    }

    /// The cluster-virtual clock (sum of epoch makespans so far).
    pub fn clock(&self) -> f64 {
        self.inner.state.lock().unwrap().clock
    }

    /// Stop the epoch thread at the next boundary. Queued/running jobs stay
    /// in their current state; further submits fail.
    pub fn shutdown(&self) {
        self.inner.state.lock().unwrap().shutdown = true;
        self.inner.wake.notify_all();
    }
}

/// What the epoch thread pulls out of the shared state to plan one epoch.
struct PlanInput {
    /// `(job id, task index)` aligned with `tasks`/`bases`.
    keys: Vec<(u64, usize)>,
    /// Remaining work as a workload (n_sims = remaining per task).
    tasks: Vec<OptionTask>,
    bases: Vec<u64>,
    /// Tightest remaining deadline slack across admitted deadline jobs.
    deadline_slack: Option<f64>,
    /// Sum of remaining budgets when EVERY admitted job is budget-SLO'd.
    budget_cap: Option<f64>,
    /// Remaining work (flops) across ALL live jobs, admitted or still
    /// queued — the autoscaler's backlog pressure.
    backlog_flops: f64,
}

/// The warm incumbent carried across epochs.
struct Warm {
    keys: Vec<(u64, usize)>,
    alloc: Allocation,
    /// Throughput snapshot of the solve that produced `alloc`.
    throughput: Vec<f64>,
    /// The batch budget cap the solve saw (None = unconstrained batch).
    budget_cap: Option<f64>,
}

/// Whether the warm incumbent's budget context still covers the batch:
/// unconstrained stays unconstrained, and a depleting all-budget cap may
/// shrink by at most `tolerance` (relative) before a re-solve under the
/// current remaining budgets is forced.
fn budget_still_covered(warm: Option<f64>, current: Option<f64>, tolerance: f64) -> bool {
    match (warm, current) {
        (None, None) => true,
        (Some(w), Some(c)) => c >= w * (1.0 - tolerance),
        _ => false,
    }
}

/// How one epoch's allocation was obtained, cheapest first. Only the two
/// `Full*` variants count as `resolved` in [`EpochRecord`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PlanKind {
    /// Incumbent projected onto the surviving keys verbatim.
    WarmReuse,
    /// New arrivals delta-admitted into the incumbent (repair).
    Incremental,
    /// A memoized plan with a matching remaining-work signature.
    MemoHit,
    /// Cold full solve (no usable incumbent existed).
    FullSolve,
    /// Full solve forced by drift or repair-quality failure.
    FullReplan,
}

/// Fluid (infinitely-divisible, setup-free) lower bound on the batch
/// makespan: all platforms run in parallel, so the harmonic sum of their
/// solo work times bounds any real schedule from below.
fn fluid_bound(models: &ModelSet, input: &PlanInput) -> f64 {
    let mut inv = 0.0f64;
    for i in 0..models.mu {
        let w: f64 = (0..input.tasks.len()).map(|j| models.work_secs(i, j)).sum();
        if w > 0.0 {
            inv += 1.0 / w;
        }
    }
    if inv > 0.0 {
        1.0 / inv
    } else {
        0.0
    }
}

/// Cheap-plan quality gate: accept a repaired/memoized allocation only if
/// its predicted makespan is within `quality`× of the fluid lower bound
/// (plus one worst-case setup, so setup-dominated small epochs are not
/// rejected forever). Failing the gate forces a full re-solve.
fn plan_quality_ok(
    alloc: &Allocation,
    models: &ModelSet,
    input: &PlanInput,
    quality: f64,
) -> bool {
    let lb = fluid_bound(models, input);
    let mut max_setup = 0.0f64;
    for i in 0..models.mu {
        for j in 0..input.tasks.len() {
            max_setup = max_setup.max(models.setup_secs(i, j));
        }
    }
    models.makespan(alloc) <= quality * lb + max_setup + 1e-9
}

/// Repair the incumbent for a batch that *grew*: surviving keys keep their
/// columns, fresh keys are placed whole, longest-first, each onto the
/// platform finishing it soonest given the inherited load. Returns `None`
/// when there is nothing to repair (no fresh keys — projection's job), the
/// shapes do not line up, or the repaired plan fails the quality gate.
fn delta_admit(
    w: &Warm,
    input: &PlanInput,
    models: &ModelSet,
    quality: f64,
) -> Option<Allocation> {
    let mu = models.mu;
    let tau = input.tasks.len();
    if w.alloc.n_platforms() != mu {
        return None;
    }
    let cols: Vec<Option<usize>> = input
        .keys
        .iter()
        .map(|k| w.keys.iter().position(|wk| wk == k))
        .collect();
    let fresh: Vec<usize> =
        (0..tau).filter(|&j| cols[j].is_none()).collect();
    if fresh.is_empty() {
        return None;
    }
    let mut a = Allocation::zero(mu, tau);
    for (j_new, col) in cols.iter().enumerate() {
        if let Some(j_old) = col {
            for i in 0..mu {
                a.set(i, j_new, w.alloc.get(i, *j_old));
            }
        }
    }
    // Inherited per-platform load under the *current* models (drift-
    // refreshed betas, rent-lead penalties included).
    let mut load: Vec<f64> = (0..mu).map(|i| models.platform_latency(&a, i)).collect();
    // LPT over the fresh tasks: biggest remaining work placed first.
    let mut order = fresh;
    order.sort_by(|&x, &y| {
        let wx = input.tasks[x].n_sims as f64 * input.tasks[x].flops_per_path();
        let wy = input.tasks[y].n_sims as f64 * input.tasks[y].flops_per_path();
        wy.partial_cmp(&wx).unwrap_or(std::cmp::Ordering::Equal).then(x.cmp(&y))
    });
    for j in order {
        let mut best = 0usize;
        let mut best_finish = f64::INFINITY;
        for i in 0..mu {
            let finish = load[i] + models.work_secs(i, j) + models.setup_secs(i, j);
            if finish < best_finish {
                best_finish = finish;
                best = i;
            }
        }
        a.set(best, j, 1.0);
        load[best] = best_finish;
    }
    if plan_quality_ok(&a, models, input, quality) {
        Some(a)
    } else {
        None
    }
}

/// Octave-quantised log bucket: `v` and any value within the same
/// `1/per_octave`-octave band map to one bucket. Non-positive and
/// non-finite values collapse to bucket 0.
fn qlog(v: f64, per_octave: f64) -> u64 {
    if v > 0.0 && v.is_finite() {
        (v.log2() * per_octave).round() as i64 as u64
    } else {
        0
    }
}

/// Memo key: FNV-1a over the *quantised* remaining-work signature of the
/// batch — per-task work buckets (positional), per-platform throughput
/// buckets, and the budget bucket. Batches whose quantised signatures
/// match are close enough for one plan to serve both (the storm case:
/// thousands of near-identical re-price batches, a handful of keys).
fn plan_signature(input: &PlanInput, throughput: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut fold = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    };
    fold(input.keys.len() as u64);
    for t in &input.tasks {
        // Whole octaves for N (doubling the work is a different batch),
        // quarter octaves for the per-path shape.
        fold(qlog(t.n_sims as f64, 1.0));
        fold(qlog(t.flops_per_path(), 4.0));
    }
    for &tp in throughput {
        fold(qlog(tp, 4.0));
    }
    fold(match input.budget_cap {
        None => u64::MAX,
        Some(b) => qlog(b, 4.0),
    });
    h
}

fn epoch_loop<F>(inner: Arc<Inner>, make_partitioner: F)
where
    F: FnOnce() -> Result<Box<dyn Partitioner>>,
{
    let partitioner = match make_partitioner() {
        Ok(p) => p,
        Err(e) => {
            // Record the fatal error for future submits AND fail any job
            // that slipped in while the factory was still running — nothing
            // will ever execute them, so leaving them Queued would hang
            // every status poller.
            let msg = format!("scheduler partitioner failed to build: {e}");
            let mut st = inner.state.lock().unwrap();
            let clock = st.clock;
            let mut failed = 0u64;
            for job in st.jobs.values_mut() {
                if !job.state.is_terminal() {
                    job.state = JobState::Failed(msg.clone());
                    job.finished_s = Some(clock);
                    job.slo_met = Some(false);
                    failed += 1;
                }
            }
            st.stats.failed += failed;
            if let Some(m) = &inner.metrics {
                m.failed.add(failed);
            }
            st.fatal = Some(e);
            return;
        }
    };
    let specs = inner.cluster.specs();
    let cost_models: Vec<CostModel> = specs.iter().map(|s| s.cost_model()).collect();
    let platform_names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let mut fit = if inner.cfg.family_refit {
        OnlineLatencyFit::new(inner.priors.clone(), inner.cfg.refit_window)
    } else {
        OnlineLatencyFit::single_line(inner.priors.clone(), inner.cfg.refit_window)
    };
    let mut warm: Option<Warm> = None;
    let mut stalled = 0usize;
    let econ: Vec<PlatformEcon> = specs
        .iter()
        .zip(&inner.priors)
        .map(|(s, p)| PlatformEcon {
            throughput_flops: p.throughput_flops,
            rate_per_hour: s.rate_per_hour,
        })
        .collect();
    let mut autoscaler = Autoscaler::new(inner.cfg.forecast.clone(), econ);
    // Memoized plans keyed on the quantised remaining-work signature: a
    // storm's thousands of near-identical batches collapse onto a handful
    // of keys, so planning cost is amortised across the burst.
    let mut memo: HashMap<u64, Allocation> = HashMap::new();

    loop {
        // ── Phase 1: wait for runnable work, admit arrivals. ────────────
        let (input, arrived_flops) = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                admit(&mut st, inner.cfg.max_in_flight);
                let runnable = st.jobs.values().any(|j| {
                    j.state == JobState::Running && j.tasks.iter().any(|t| t.remaining > 0)
                });
                if runnable {
                    break;
                }
                st = inner.wake.wait(st).unwrap();
            }
            let arrived = std::mem::take(&mut st.arrived_flops);
            (collect_plan_input(&st), arrived)
        };
        if input.tasks.is_empty() {
            continue;
        }
        // One span per epoch: plan → execute → apply.
        let _span = crate::span!("scheduler_epoch");

        // ── Predictive autoscaling: observe arrivals, forecast, re-rent.
        // With `[forecast]` disabled everything stays rented (the static
        // over-provisioned baseline); enabled, the forecaster pre-rents
        // ahead of predicted storms and drains idle rentals after.
        let rented: Vec<bool> = autoscaler
            .plan(arrived_flops, input.backlog_flops, inner.cfg.epoch_secs)
            .to_vec();

        // ── Phase 2: refreshed models for the batch. ────────────────────
        let tau = input.tasks.len();
        let mu = inner.cluster.len();
        let lead = inner.cfg.forecast.rent_lead_secs;
        let mut latency = Vec::with_capacity(mu * tau);
        for i in 0..mu {
            for t in &input.tasks {
                let base = fit.model(i, t.payoff, t.flops_per_path());
                // Un-rented platforms stay usable mid-storm, but pay the
                // rent lead (API/boot) on top of their setup — the planner
                // steers work onto pre-rented capacity first.
                latency.push(if rented[i] {
                    base
                } else {
                    LatencyModel::new(base.beta, base.gamma + lead)
                });
            }
        }
        let models = ModelSet::new(
            latency,
            cost_models.clone(),
            input.tasks.iter().map(|t| t.n_sims).collect(),
            platform_names.clone(),
        )
        .with_task_families(input.tasks.iter().map(|t| t.payoff).collect());

        // ── Phase 3: warm-reuse, delta-admit, memo, or re-solve. ────────
        let snapshot = fit.snapshot();
        // The incumbent survives task completions (its columns project
        // onto the surviving keys) but not new arrivals.
        let projected = warm.as_ref().and_then(|w| project_warm(w, &input.keys));
        let warm_pred = projected.as_ref().map(|a| models.makespan(a));
        let reuse_ok = warm
            .as_ref()
            .map(|w| {
                fit.drift(&w.throughput) <= inner.cfg.resolve_drift
                    && budget_still_covered(
                        w.budget_cap,
                        input.budget_cap,
                        inner.cfg.resolve_drift,
                    )
            })
            .unwrap_or(false);
        let had_warm = warm.is_some();
        let sig = plan_signature(&input, &snapshot);
        let mut plan: Option<(Allocation, Option<f64>, PlanKind, f64, f64)> = None;
        if reuse_ok {
            if let (Some(a), Some(pred)) = (projected, warm_pred) {
                let budget = warm.as_ref().and_then(|w| w.budget_cap);
                plan = Some((a, budget, PlanKind::WarmReuse, pred, 0.0));
            } else {
                // New keys defeated the projection (the storm case): try
                // delta-admitting them into the incumbent before paying
                // for a cold solve.
                let t0 = Instant::now();
                if let Some(a) = delta_admit(
                    warm.as_ref().expect("reuse_ok implies an incumbent"),
                    &input,
                    &models,
                    inner.cfg.repair_quality,
                ) {
                    let secs = t0.elapsed().as_secs_f64();
                    let pred = models.makespan(&a);
                    warm = Some(Warm {
                        keys: input.keys.clone(),
                        alloc: a.clone(),
                        throughput: snapshot.clone(),
                        budget_cap: input.budget_cap,
                    });
                    plan = Some((a, input.budget_cap, PlanKind::Incremental, pred, secs));
                }
            }
        }
        // Memoized plans only stand in for unconstrained solves — budget
        // caps change what "optimal" means, so capped batches always pay
        // the real solve.
        if plan.is_none() && input.budget_cap.is_none() {
            if let Some(a) = memo.get(&sig) {
                if a.n_platforms() == mu
                    && a.n_tasks() == tau
                    && plan_quality_ok(a, &models, &input, inner.cfg.repair_quality)
                {
                    let a = a.clone();
                    let pred = models.makespan(&a);
                    warm = Some(Warm {
                        keys: input.keys.clone(),
                        alloc: a.clone(),
                        throughput: snapshot.clone(),
                        budget_cap: input.budget_cap,
                    });
                    plan = Some((a, input.budget_cap, PlanKind::MemoHit, pred, 0.0));
                }
            }
        }
        let (alloc, budget, plan_kind, predicted, plan_secs) = match plan {
            Some(p) => p,
            None => {
                let t0 = Instant::now();
                match plan_allocation(partitioner.as_ref(), &models, &input) {
                    Ok((alloc, budget)) => {
                        let secs = t0.elapsed().as_secs_f64();
                        let pred = models.makespan(&alloc);
                        if inner.cfg.plan_memo > 0 && budget.is_none() {
                            if memo.len() >= inner.cfg.plan_memo {
                                memo.clear();
                            }
                            memo.insert(sig, alloc.clone());
                        }
                        warm = Some(Warm {
                            keys: input.keys.clone(),
                            alloc: alloc.clone(),
                            throughput: snapshot,
                            budget_cap: input.budget_cap,
                        });
                        let kind =
                            if had_warm { PlanKind::FullReplan } else { PlanKind::FullSolve };
                        (alloc, budget, kind, pred, secs)
                    }
                    Err(e) => {
                        fail_running_jobs(&inner, &format!("epoch solve failed: {e}"));
                        warm = None;
                        continue;
                    }
                }
            }
        };
        let resolved = matches!(plan_kind, PlanKind::FullSolve | PlanKind::FullReplan);

        // ── Phase 4: execute one epoch. ─────────────────────────────────
        let workload = Workload::new(input.tasks.clone());
        let mut exec_cfg = inner.exec.clone();
        exec_cfg.chunk_sims = epoch_chunk_cap(&inner.exec, &models, inner.cfg.epoch_secs);
        let mut err_sum = 0.0f64;
        let mut err_n = 0usize;
        let outcome = {
            let fit = &mut fit;
            let models_ref = &models;
            let workload_ref = &workload;
            let reg = &inner.reg;
            let platform_names = &platform_names;
            execute_epoch(
                &inner.cluster,
                workload_ref,
                &alloc,
                &exec_cfg,
                Some(models_ref),
                EpochCtx { halt_secs: inner.cfg.epoch_secs, base_offsets: &input.bases },
                &mut |ev| {
                    if let ExecEvent::ChunkDone {
                        platform, task, n, latency_secs, cold, ..
                    } = ev
                    {
                        let m = models_ref.model(*platform, *task);
                        let setup = if *cold { m.gamma } else { 0.0 };
                        let predicted = m.beta * *n as f64 + setup;
                        if *latency_secs > 0.0 {
                            err_sum += (predicted - latency_secs).abs() / latency_secs;
                            err_n += 1;
                        }
                        // Work-only throughput sample. A cold chunk whose
                        // measured latency is below the *modelled* setup
                        // carries no usable work signal (the true setup is
                        // itself noisy) — observe() drops the non-positive
                        // sample instead of us clamping it into a bogus
                        // near-infinite throughput.
                        let family = workload_ref.tasks[*task].payoff;
                        let flops = workload_ref.tasks[*task].flops_per_path() * *n as f64;
                        fit.observe(*platform, family, flops, latency_secs - setup);
                        if let Some(reg) = reg {
                            reg.observe(
                                "exec_chunk_latency_secs",
                                &format!("platform={}", platform_names[*platform]),
                                *latency_secs,
                            );
                            if *latency_secs > 0.0 {
                                reg.observe(
                                    "exec_model_error_rel",
                                    &format!(
                                        "platform={},task={task},family={}",
                                        platform_names[*platform],
                                        family.name()
                                    ),
                                    (predicted - latency_secs).abs() / latency_secs,
                                );
                            }
                        }
                    }
                },
            )
        };
        let outcome = match outcome {
            Ok(o) => o,
            Err(e) => {
                fail_running_jobs(&inner, &format!("epoch execution failed: {e}"));
                warm = None;
                continue;
            }
        };

        // ── Phase 5: apply the epoch's results. ─────────────────────────
        let epoch_done: u64 = outcome.done_sims.iter().sum();
        let model_error = if err_n > 0 { err_sum / err_n as f64 } else { 0.0 };
        let mut st = inner.state.lock().unwrap();
        let clock_before = st.clock;
        st.clock += outcome.exec.makespan_secs;
        let clock_after = st.clock;

        // Attribute the epoch's bill by executed work.
        let total_flops: f64 = outcome
            .done_sims
            .iter()
            .zip(&input.tasks)
            .map(|(&d, t)| d as f64 * t.flops_per_path())
            .sum();
        for (slot, (&(job_id, task_idx), &done)) in
            input.keys.iter().zip(&outcome.done_sims).enumerate()
        {
            let requested = input.tasks[slot].n_sims;
            let share = if total_flops > 0.0 {
                done as f64 * input.tasks[slot].flops_per_path() / total_flops
            } else {
                0.0
            };
            let Some(job) = st.jobs.get_mut(&job_id) else { continue };
            if job.state != JobState::Running {
                continue; // cancelled (or failed) mid-epoch: drop the results
            }
            let jt = &mut job.tasks[task_idx];
            jt.remaining = jt.remaining.saturating_sub(done);
            jt.cursor += requested;
            jt.stats = jt.stats.merge(&outcome.stats[slot]);
            job.sims_done += done;
            job.cost += outcome.exec.cost * share;
        }
        // Per-job bookkeeping: epochs, predictions, completion, SLOs.
        // Keys are grouped per job (collect_plan_input walks jobs in id
        // order), so dedup over the consecutive run is exact.
        let mut participant_ids: Vec<u64> =
            input.keys.iter().map(|&(id, _)| id).collect();
        participant_ids.dedup();
        for id in &participant_ids {
            let Some(job) = st.jobs.get_mut(id) else { continue };
            if job.state != JobState::Running {
                continue;
            }
            job.epochs += 1;
            job.predicted_finish_s = Some(clock_before + predicted);
            if job.tasks.iter().all(|t| t.remaining == 0) {
                job.state = JobState::Done;
                job.finished_s = Some(clock_after);
                job.slo_met = Some(match job.slo {
                    Slo::Deadline(d) => clock_after - job.arrival_s <= d + 1e-9,
                    Slo::Budget(b) => job.cost <= b + 1e-9,
                });
                st.stats.completed += 1;
                if let Some(m) = &inner.metrics {
                    m.completed.inc();
                }
            }
        }
        // Stall guard: epochs that complete nothing, repeatedly, mean the
        // cluster cannot make progress (e.g. everything preempted).
        if epoch_done == 0 {
            stalled += 1;
        } else {
            stalled = 0;
        }
        if stalled >= MAX_STALLED_EPOCHS {
            let msg = format!("no progress in {MAX_STALLED_EPOCHS} consecutive epochs");
            let clock = st.clock;
            let mut failed = 0u64;
            for job in st.jobs.values_mut() {
                if job.state == JobState::Running {
                    job.state = JobState::Failed(msg.clone());
                    job.finished_s = Some(clock);
                    job.slo_met = Some(false);
                    failed += 1;
                }
            }
            st.stats.failed += failed;
            if let Some(m) = &inner.metrics {
                m.failed.add(failed);
            }
            stalled = 0;
            warm = None;
        }
        // Idle holding cost: rented-but-unused platforms bill the operator
        // for the epoch even though no job's budget is charged — this is
        // the waste predictive autoscaling exists to trim.
        let used = alloc.used_platforms();
        for (i, spec) in specs.iter().enumerate() {
            if rented[i] && !used.contains(&i) {
                st.stats.idle_cost +=
                    spec.rate_per_hour / 3600.0 * outcome.exec.makespan_secs;
            }
        }
        st.stats.rented_instances = rented.iter().filter(|&&r| r).count();
        st.stats.forecast_error = autoscaler.forecast_error();
        // Epoch record + counters.
        st.stats.epochs += 1;
        match plan_kind {
            PlanKind::WarmReuse => st.stats.warm_reuses += 1,
            PlanKind::Incremental => {
                st.stats.replans_incremental += 1;
                st.stats.plan_secs_incremental += plan_secs;
            }
            PlanKind::MemoHit => st.stats.memo_hits += 1,
            PlanKind::FullSolve | PlanKind::FullReplan => {
                st.stats.resolves += 1;
                st.stats.plan_secs_full += plan_secs;
                if plan_kind == PlanKind::FullReplan {
                    st.stats.replans_full += 1;
                }
            }
        }
        let first_error = st.stats.first_model_error.is_none() && err_n > 0;
        if first_error {
            st.stats.first_model_error = Some(model_error);
        }
        if err_n > 0 {
            st.stats.last_model_error = Some(model_error);
        }
        if let Some(m) = &inner.metrics {
            m.epochs.inc();
            match plan_kind {
                PlanKind::WarmReuse => m.warm_reuses.inc(),
                PlanKind::Incremental => m.replans_incremental.inc(),
                PlanKind::MemoHit => m.memo_hits.inc(),
                PlanKind::FullSolve | PlanKind::FullReplan => {
                    m.resolves.inc();
                    if plan_kind == PlanKind::FullReplan {
                        m.replans_full.inc();
                    }
                }
            }
            m.rented_instances.set(st.stats.rented_instances as f64);
            if let Some(err) = st.stats.forecast_error {
                m.forecast_error.set(err);
            }
            if first_error {
                m.model_error_first.set(model_error);
            }
            if err_n > 0 {
                m.model_error_last.set(model_error);
                m.epoch_model_error.observe(model_error);
            }
        }
        let record = EpochRecord {
            epoch: st.stats.epochs,
            jobs: participant_ids.len(),
            tasks: tau,
            resolved,
            budget,
            warm_makespan_s: warm_pred,
            predicted_makespan_s: predicted,
            measured_epoch_s: outcome.exec.makespan_secs,
            epoch_cost: outcome.exec.cost,
            model_error,
        };
        st.stats.records.push(record);
        if st.stats.records.len() > MAX_EPOCH_RECORDS {
            st.stats.records.remove(0);
        }
    }
}

/// Admit queued jobs while in-flight slots are free (submission order).
fn admit(st: &mut SchedState, max_in_flight: usize) {
    let mut running =
        st.jobs.values().filter(|j| j.state == JobState::Running).count();
    let queued: Vec<u64> = st
        .jobs
        .values()
        .filter(|j| j.state == JobState::Queued)
        .map(|j| j.id)
        .collect();
    for id in queued {
        if running >= max_in_flight {
            break;
        }
        st.jobs.get_mut(&id).unwrap().state = JobState::Running;
        running += 1;
    }
}

/// Gather the epoch batch: every running job's remaining tasks, plus the
/// SLO aggregates the budget policy needs.
fn collect_plan_input(st: &SchedState) -> PlanInput {
    let mut keys = Vec::new();
    let mut tasks = Vec::new();
    let mut bases = Vec::new();
    let mut deadline_slack: Option<f64> = None;
    let mut budget_cap = Some(0.0f64);
    let mut backlog_flops = 0.0f64;
    for job in st.jobs.values() {
        if !job.state.is_terminal() {
            backlog_flops += job
                .tasks
                .iter()
                .map(|jt| jt.remaining as f64 * jt.task.flops_per_path())
                .sum::<f64>();
        }
        if job.state != JobState::Running {
            continue;
        }
        match job.slo {
            Slo::Deadline(d) => {
                let slack = d - (st.clock - job.arrival_s);
                deadline_slack =
                    Some(deadline_slack.map_or(slack, |s: f64| s.min(slack)));
                budget_cap = None; // mixed batch: budgets no longer cover it
            }
            Slo::Budget(b) => {
                if let Some(cap) = budget_cap.as_mut() {
                    *cap += (b - job.cost).max(0.0);
                }
            }
        }
        for (k, jt) in job.tasks.iter().enumerate() {
            if jt.remaining == 0 {
                continue;
            }
            let mut task = jt.task.clone();
            task.n_sims = jt.remaining;
            keys.push((job.id, k));
            tasks.push(task);
            bases.push(jt.cursor);
        }
    }
    PlanInput { keys, tasks, bases, deadline_slack, budget_cap, backlog_flops }
}

/// Project the warm incumbent onto the current key set: identical key
/// lists reuse the allocation verbatim; a *shrunken* set (tasks completed)
/// keeps the surviving columns (each still sums to 1); any new key means
/// the incumbent cannot cover the batch (`None` ⇒ re-solve).
fn project_warm(w: &Warm, keys: &[(u64, usize)]) -> Option<Allocation> {
    if w.keys == keys {
        return Some(w.alloc.clone());
    }
    let cols: Option<Vec<usize>> = keys
        .iter()
        .map(|k| w.keys.iter().position(|wk| wk == k))
        .collect();
    let cols = cols?;
    let mu = w.alloc.n_platforms();
    let mut a = Allocation::zero(mu, cols.len());
    for (j_new, &j_old) in cols.iter().enumerate() {
        for i in 0..mu {
            a.set(i, j_new, w.alloc.get(i, j_old));
        }
    }
    Some(a)
}

/// The epoch budget policy: deadline jobs buy speed, budget jobs buy
/// thrift.
///
/// - Any deadline job with slack under twice the unconstrained remaining
///   makespan ⇒ run unconstrained (minimum makespan);
/// - an all-budget batch ⇒ solve under the sum of remaining budgets
///   (falling back to unconstrained when that is infeasible);
/// - otherwise unconstrained.
fn plan_allocation(
    partitioner: &dyn Partitioner,
    models: &ModelSet,
    input: &PlanInput,
) -> Result<(Allocation, Option<f64>)> {
    let alloc_u = partitioner.partition(models, None)?;
    let makespan_u = models.makespan(&alloc_u);
    let tight = input
        .deadline_slack
        .map(|s| s < 2.0 * makespan_u)
        .unwrap_or(false);
    if !tight {
        if let Some(cap) = input.budget_cap {
            if cap > 0.0 {
                if let Ok(a) = partitioner.partition(models, Some(cap)) {
                    return Ok((a, Some(cap)));
                }
            }
        }
    }
    Ok((alloc_u, None))
}

/// Mark every running job failed (epoch-level solver/executor breakdowns).
fn fail_running_jobs(inner: &Inner, msg: &str) {
    let mut st = inner.state.lock().unwrap();
    let clock = st.clock;
    let mut failed = 0u64;
    for job in st.jobs.values_mut() {
        if job.state == JobState::Running {
            job.state = JobState::Failed(msg.to_string());
            job.finished_s = Some(clock);
            job.slo_met = Some(false);
            failed += 1;
        }
    }
    st.stats.failed += failed;
    if let Some(m) = &inner.metrics {
        m.failed.add(failed);
    }
}

/// Chunks must be fine enough for the epoch boundary to bite on EVERY
/// lane: cap one chunk at ~1/8 of the epoch on the *slowest* (platform,
/// task) pairing, inside the configured `chunk_sims`. Sizing from the
/// fastest pairing instead would let a single chunk occupy a slow lane for
/// many whole epochs (Table II throughputs span two orders of magnitude),
/// making the boundary — and with it cancellation and re-planning —
/// unenforceable on exactly the lanes that need it most.
fn epoch_chunk_cap(exec: &ExecutorConfig, models: &ModelSet, epoch_secs: f64) -> u64 {
    let mut max_beta = 0.0f64;
    for i in 0..models.mu {
        for j in 0..models.tau {
            max_beta = max_beta.max(models.model(i, j).beta);
        }
    }
    let cap = if max_beta.is_finite() && max_beta > 0.0 {
        ((epoch_secs / 8.0) / max_beta).max(1.0).min(u64::MAX as f64) as u64
    } else {
        u64::MAX
    };
    let base = if exec.chunk_sims == 0 { u64::MAX } else { exec.chunk_sims };
    base.min(cap).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioner::HeuristicPartitioner;
    use crate::models::online::PlatformPrior;
    use crate::platforms::sim::SimConfig;
    use crate::platforms::spec::small_cluster;
    use std::time::{Duration, Instant};

    fn cluster() -> Cluster {
        Cluster::simulated(&small_cluster(), &SimConfig::exact(), 21).unwrap()
    }

    fn priors(cluster: &Cluster) -> Vec<PlatformPrior> {
        cluster
            .specs()
            .iter()
            .map(|s| PlatformPrior {
                throughput_flops: s.app_gflops.max(1e-9) * 1e9,
                setup_secs: s.setup_secs,
            })
            .collect()
    }

    fn start(cfg: SchedulerConfig) -> OnlineScheduler {
        let c = cluster();
        let p = priors(&c);
        OnlineScheduler::start(c, p, ExecutorConfig::default(), cfg, || {
            Ok(Box::new(HeuristicPartitioner::default()))
        })
        .unwrap()
    }

    fn wait_terminal(s: &OnlineScheduler, id: u64) -> JobStatus {
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let st = s.job_status(id).expect("job tracked");
            if st.state.is_terminal() {
                return st;
            }
            assert!(Instant::now() < deadline, "job {id} never finished: {st:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    #[test]
    fn job_spec_validation() {
        assert!(JobSpec::new(vec![], Slo::Deadline(10.0)).is_err());
        let ok = JobSpec::generate(Some(Payoff::Asian), 2, 0.05, 3, Slo::Budget(5.0)).unwrap();
        assert_eq!(ok.tasks.len(), 2);
        assert!(ok.tasks.iter().all(|t| t.payoff == Payoff::Asian));
        // Bad SLOs are workload errors.
        let e = JobSpec::generate(None, 1, 0.05, 3, Slo::Deadline(-1.0)).unwrap_err();
        assert_eq!(e.kind(), "workload");
        let e = JobSpec::generate(None, 1, 0.05, 3, Slo::Budget(f64::NAN)).unwrap_err();
        assert_eq!(e.kind(), "workload");
        // Bad generator parameters surface too.
        assert!(JobSpec::generate(None, 0, 0.05, 3, Slo::Budget(1.0)).is_err());
    }

    #[test]
    fn scheduler_config_validation() {
        assert!(SchedulerConfig::default().validate().is_ok());
        let bad = SchedulerConfig { epoch_secs: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SchedulerConfig { max_in_flight: 0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SchedulerConfig { resolve_drift: -1.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = SchedulerConfig { repair_quality: 0.5, ..Default::default() };
        assert!(bad.validate().is_err());
        // plan_memo = 0 just disables memoization.
        let ok = SchedulerConfig { plan_memo: 0, ..Default::default() };
        assert!(ok.validate().is_ok());
        // Nested forecast knobs surface through the scheduler validate.
        let mut bad = SchedulerConfig::default();
        bad.forecast.alpha = 0.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn single_job_completes_and_prices() {
        let s = start(SchedulerConfig { enabled: true, ..Default::default() });
        let job = JobSpec::generate(None, 3, 0.05, 11, Slo::Deadline(1e9)).unwrap();
        let id = s.submit(job).unwrap();
        let st = wait_terminal(&s, id);
        assert_eq!(st.state, JobState::Done);
        assert_eq!(st.slo_met, Some(true));
        assert_eq!(st.sims_done, st.sims_total);
        assert!(st.cost > 0.0);
        assert!(st.finished_s.unwrap() > 0.0);
        assert!(st.prices.iter().all(Option::is_some));
        let stats = s.stats();
        assert!(stats.epochs >= 1);
        assert_eq!(stats.completed, 1);
        // Unknown ids are None; cancel after completion is Some(false).
        assert!(s.job_status(999).is_none());
        assert_eq!(s.cancel(id), Some(false));
        assert_eq!(s.cancel(999), None);
        s.shutdown();
        assert!(s.submit(JobSpec::generate(None, 1, 0.05, 1, Slo::Budget(1.0)).unwrap())
            .is_err());
    }

    #[test]
    fn overload_refusal_is_typed_and_counted() {
        let c = cluster();
        let p = priors(&c);
        let reg = Arc::new(MetricsRegistry::default());
        // Park the epoch thread in the factory so no job ever leaves the
        // registry: the 1025th live submit must shed.
        let s = OnlineScheduler::start_instrumented(
            c,
            p,
            ExecutorConfig::default(),
            SchedulerConfig { enabled: true, ..Default::default() },
            Some(reg.clone()),
            || {
                std::thread::sleep(Duration::from_secs(60));
                Ok(Box::new(HeuristicPartitioner::default()))
            },
        )
        .unwrap();
        for k in 0..MAX_TRACKED_JOBS {
            let job =
                JobSpec::generate(Some(Payoff::European), 1, 0.5, k as u64, Slo::Deadline(1e9))
                    .unwrap();
            s.submit(job).unwrap();
        }
        let job = JobSpec::generate(Some(Payoff::European), 1, 0.5, 9999, Slo::Deadline(1e9))
            .unwrap();
        let e = s.submit(job).unwrap_err();
        assert_eq!(e.kind(), "overload");
        assert_eq!(reg.counter_value("serve_shed_total", "reason=jobs_full"), 1);
        s.shutdown();
    }

    /// Builds a 6-task batch whose first 4 keys carry a warm incumbent;
    /// delta-admitting the 2 fresh keys must stay within the repair
    /// quality gate of the full re-solve.
    #[test]
    fn delta_admit_matches_full_solve_on_no_drift_epoch() {
        let specs = small_cluster();
        let w6 = crate::workload::generate(&crate::workload::GeneratorConfig::small(6, 0.1, 9));
        let models6 = crate::coordinator::ModelSet::from_specs(&specs, &w6);
        let keys6: Vec<(u64, usize)> = (0..6).map(|j| (0u64, j)).collect();
        let input = PlanInput {
            keys: keys6,
            tasks: w6.tasks.clone(),
            bases: vec![0; 6],
            deadline_slack: None,
            budget_cap: None,
            backlog_flops: 0.0,
        };
        let part = HeuristicPartitioner::default();
        // Incumbent over the first 4 tasks only.
        let w4 = Workload::new(w6.tasks[..4].to_vec());
        let models4 = crate::coordinator::ModelSet::from_specs(&specs, &w4);
        let alloc4 = part.partition(&models4, None).unwrap();
        let warm = Warm {
            keys: (0..4).map(|j| (0u64, j)).collect(),
            alloc: alloc4,
            throughput: specs.iter().map(|s| s.app_gflops * 1e9).collect(),
            budget_cap: None,
        };
        let quality = SchedulerConfig::default().repair_quality;
        let repaired = delta_admit(&warm, &input, &models6, quality)
            .expect("repair passes the quality gate on a no-drift epoch");
        repaired.validate().unwrap();
        let full = part.partition(&models6, None).unwrap();
        let mut max_setup = 0.0f64;
        for i in 0..models6.mu {
            for j in 0..6 {
                max_setup = max_setup.max(models6.setup_secs(i, j));
            }
        }
        // The gate bounds the repair against the fluid LB; the full solve
        // sits above that LB, so quality x full + setup bounds the repair.
        assert!(
            models6.makespan(&repaired)
                <= quality * models6.makespan(&full) + max_setup + 1e-9,
            "repair makespan {} vs full {}",
            models6.makespan(&repaired),
            models6.makespan(&full)
        );
        // Nothing fresh -> nothing to repair (projection's job).
        let covered = Warm {
            keys: input.keys.clone(),
            alloc: part.partition(&models6, None).unwrap(),
            throughput: warm.throughput.clone(),
            budget_cap: None,
        };
        assert!(delta_admit(&covered, &input, &models6, quality).is_none());
    }

    #[test]
    fn plan_signature_quantises_remaining_work() {
        let w = crate::workload::generate(&crate::workload::GeneratorConfig::small(1, 0.1, 5));
        let input_with = |n: u64| {
            let mut tasks = w.tasks.clone();
            tasks[0].n_sims = n;
            PlanInput {
                keys: vec![(0, 0)],
                tasks,
                bases: vec![0],
                deadline_slack: None,
                budget_cap: None,
                backlog_flops: 0.0,
            }
        };
        let tp = [1e9, 2e9, 4e9];
        // Same log2 bucket (both round to 20 octaves): one memo key.
        let a = plan_signature(&input_with(1 << 20), &tp);
        let b = plan_signature(&input_with(1_000_000), &tp);
        assert_eq!(a, b);
        // 4x the remaining work is a different batch.
        let c = plan_signature(&input_with(1 << 22), &tp);
        assert_ne!(a, c);
        // Budget-capped batches never alias unconstrained ones.
        let mut capped = input_with(1 << 20);
        capped.budget_cap = Some(10.0);
        assert_ne!(a, plan_signature(&capped, &tp));
    }

    #[test]
    fn epoch_chunk_cap_scales_with_models() {
        let c = cluster();
        let w = crate::workload::generate(&crate::workload::GeneratorConfig::small(2, 0.05, 1));
        let m = crate::coordinator::ModelSet::from_specs(&c.specs(), &w);
        let exec = ExecutorConfig::default();
        let cap = epoch_chunk_cap(&exec, &m, 100.0);
        assert!(cap >= 1);
        assert!(cap <= exec.chunk_sims);
        // A tiny epoch forces tiny chunks.
        let tiny = epoch_chunk_cap(&exec, &m, 1e-6);
        assert!(tiny < cap);
    }
}
