//! The allocation matrix **A** ∈ ℝ₊^(μ×τ) — the decision variable of the
//! paper's optimisation (Eq. 3): `A[i][j]` is the fraction of task `j`'s
//! simulations assigned to platform `i`. Columns sum to 1 (every task fully
//! allocated); entries are real-valued because tasks are divisible
//! ("relaxed" allocation, §III.B).

use crate::api::error::{CloudshapesError, Result};

/// Column-sum tolerance for validity checks.
pub const ALLOC_TOL: f64 = 1e-6;

/// A (μ platforms × τ tasks) allocation, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct Allocation {
    mu: usize,
    tau: usize,
    a: Vec<f64>,
}

impl Allocation {
    /// All-zero allocation (invalid until columns are filled).
    pub fn zero(mu: usize, tau: usize) -> Allocation {
        assert!(mu > 0 && tau > 0, "degenerate allocation shape");
        Allocation { mu, tau, a: vec![0.0; mu * tau] }
    }

    /// Allocate every task wholly to platform `i`.
    pub fn single_platform(mu: usize, tau: usize, i: usize) -> Allocation {
        let mut al = Allocation::zero(mu, tau);
        for j in 0..tau {
            al.set(i, j, 1.0);
        }
        al
    }

    /// Same proportional split `weights[i] / Σ weights` for every task.
    pub fn proportional(mu: usize, tau: usize, weights: &[f64]) -> Allocation {
        assert_eq!(weights.len(), mu);
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weights must have positive sum");
        let mut al = Allocation::zero(mu, tau);
        for i in 0..mu {
            for j in 0..tau {
                al.set(i, j, weights[i] / total);
            }
        }
        al
    }

    pub fn n_platforms(&self) -> usize {
        self.mu
    }

    pub fn n_tasks(&self) -> usize {
        self.tau
    }

    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.a[i * self.tau + j]
    }

    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        assert!(v >= -ALLOC_TOL && v.is_finite(), "allocation entry {v}");
        self.a[i * self.tau + j] = v.max(0.0);
    }

    /// Column sum for task `j`.
    pub fn column_sum(&self, j: usize) -> f64 {
        (0..self.mu).map(|i| self.get(i, j)).sum()
    }

    /// Re-scale every column to sum to exactly 1 (fails on zero columns).
    pub fn normalise(&mut self) -> Result<()> {
        for j in 0..self.tau {
            let s = self.column_sum(j);
            if s <= ALLOC_TOL {
                return Err(CloudshapesError::solver(format!("task {j} has no allocation")));
            }
            for i in 0..self.mu {
                self.a[i * self.tau + j] /= s;
            }
        }
        Ok(())
    }

    /// Validity: non-negative entries, all columns sum to 1.
    pub fn validate(&self) -> Result<()> {
        for (idx, v) in self.a.iter().enumerate() {
            if *v < 0.0 || !v.is_finite() {
                return Err(CloudshapesError::solver(format!("entry {idx} invalid: {v}")));
            }
        }
        for j in 0..self.tau {
            let s = self.column_sum(j);
            if (s - 1.0).abs() > ALLOC_TOL * self.mu as f64 {
                return Err(CloudshapesError::solver(format!("task {j} allocation sums to {s}")));
            }
        }
        Ok(())
    }

    /// Platforms with any assigned work.
    pub fn used_platforms(&self) -> Vec<usize> {
        (0..self.mu)
            .filter(|i| (0..self.tau).any(|j| self.get(*i, j) > ALLOC_TOL))
            .collect()
    }

    /// Integer split of task `j`'s `n` simulations across platforms using
    /// the largest-remainder method. Guarantees `Σᵢ out[i] == n` exactly.
    pub fn split_sims(&self, j: usize, n: u64) -> Vec<u64> {
        let shares: Vec<f64> = (0..self.mu).map(|i| self.get(i, j)).collect();
        largest_remainder(&shares, n)
    }
}

/// Apportion `n` items by fractional `shares` (assumed to sum to ~1) using
/// the largest-remainder method; total is preserved exactly.
pub fn largest_remainder(shares: &[f64], n: u64) -> Vec<u64> {
    let total_share: f64 = shares.iter().sum();
    assert!(total_share > ALLOC_TOL, "no positive shares");
    let exact: Vec<f64> = shares.iter().map(|s| s / total_share * n as f64).collect();
    let mut out: Vec<u64> = exact.iter().map(|e| e.floor() as u64).collect();
    let assigned: u64 = out.iter().sum();
    let mut rem: Vec<(usize, f64)> =
        exact.iter().enumerate().map(|(i, e)| (i, e - e.floor())).collect();
    rem.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
    for k in 0..(n - assigned) as usize {
        out[rem[k % rem.len()].0] += 1;
    }
    debug_assert_eq!(out.iter().sum::<u64>(), n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{prop_assert, prop_check};

    #[test]
    fn single_platform_is_valid() {
        let a = Allocation::single_platform(4, 7, 2);
        assert!(a.validate().is_ok());
        assert_eq!(a.used_platforms(), vec![2]);
        assert_eq!(a.get(2, 3), 1.0);
        assert_eq!(a.get(1, 3), 0.0);
    }

    #[test]
    fn proportional_is_valid() {
        let a = Allocation::proportional(3, 5, &[1.0, 2.0, 1.0]);
        assert!(a.validate().is_ok());
        assert!((a.get(1, 0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zero_columns_fail_validation() {
        let a = Allocation::zero(2, 2);
        assert!(a.validate().is_err());
    }

    #[test]
    fn normalise_fixes_scale() {
        let mut a = Allocation::zero(2, 2);
        a.set(0, 0, 2.0);
        a.set(1, 0, 2.0);
        a.set(0, 1, 0.1);
        a.normalise().unwrap();
        assert!(a.validate().is_ok());
        assert!((a.get(0, 0) - 0.5).abs() < 1e-12);
        assert!((a.get(0, 1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn normalise_rejects_empty_column() {
        let mut a = Allocation::zero(2, 2);
        a.set(0, 0, 1.0);
        assert!(a.normalise().is_err());
    }

    #[test]
    fn split_preserves_total_exactly() {
        prop_check("largest-remainder preserves totals", 300, |g| {
            let mu = g.usize(1, 12);
            let shares: Vec<f64> = (0..mu).map(|_| g.f64(0.0, 1.0)).collect();
            if shares.iter().sum::<f64>() <= ALLOC_TOL {
                return Ok(()); // degenerate draw; skip
            }
            let n = g.usize(1, 10_000_000) as u64;
            let split = largest_remainder(&shares, n);
            prop_assert(split.iter().sum::<u64>() == n, "total changed")
        });
    }

    #[test]
    fn split_is_proportional() {
        let split = largest_remainder(&[0.5, 0.25, 0.25], 1000);
        assert_eq!(split, vec![500, 250, 250]);
    }

    #[test]
    fn split_handles_indivisible_remainders() {
        let split = largest_remainder(&[1.0, 1.0, 1.0], 10);
        assert_eq!(split.iter().sum::<u64>(), 10);
        assert!(split.iter().all(|&s| s == 3 || s == 4));
    }

    #[test]
    fn split_sims_uses_columns() {
        let mut a = Allocation::zero(2, 2);
        a.set(0, 0, 0.75);
        a.set(1, 0, 0.25);
        a.set(0, 1, 1.0);
        assert_eq!(a.split_sims(0, 100), vec![75, 25]);
        assert_eq!(a.split_sims(1, 100), vec![100, 0]);
    }

    #[test]
    #[should_panic(expected = "allocation entry")]
    fn rejects_negative_entries() {
        Allocation::zero(1, 1).set(0, 0, -0.5);
    }
}
