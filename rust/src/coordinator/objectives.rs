//! The task- and platform-reduction functions of Eq. 3:
//!
//! ```text
//! G_L(A)ᵢ = Σⱼ (βᵢⱼ Nⱼ Aᵢⱼ + γᵢⱼ ⌈Aᵢⱼ⌉)      per-platform latency
//! F_L(A)  = maxᵢ G_L(A)ᵢ                       makespan
//! G_C(A)ᵢ = πᵢ ⌈G_L(A)ᵢ / ρᵢ⌉                  per-platform billed cost
//! F_C(A)  = Σᵢ G_C(A)ᵢ                         total cost
//! ```
//!
//! plus [`ModelSet`], the (task × platform) model matrix the partitioners
//! consume — built either from fitted benchmark models (the paper's method)
//! or directly from platform specs (nominal models, for tests/ablations).

use crate::api::error::{CloudshapesError, Result};
use crate::models::{CostModel, LatencyModel};
use crate::platforms::spec::PlatformSpec;
use crate::workload::{Payoff, Workload};

use super::allocation::{Allocation, ALLOC_TOL};

/// Per-(platform, task) latency models plus per-platform billing terms.
#[derive(Debug, Clone)]
pub struct ModelSet {
    pub mu: usize,
    pub tau: usize,
    /// Row-major (platform-major) latency models.
    latency: Vec<LatencyModel>,
    /// Per-platform billing.
    pub cost: Vec<CostModel>,
    /// Simulations per task (N_j).
    pub n_sims: Vec<u64>,
    /// Platform names for reporting.
    pub platform_names: Vec<String>,
    /// Payoff family per task — empty when unknown (hand-built sets);
    /// populated via [`with_task_families`](Self::with_task_families) so
    /// reports can aggregate model quality per family.
    families: Vec<Payoff>,
}

impl ModelSet {
    pub fn new(
        latency: Vec<LatencyModel>,
        cost: Vec<CostModel>,
        n_sims: Vec<u64>,
        platform_names: Vec<String>,
    ) -> ModelSet {
        let mu = cost.len();
        let tau = n_sims.len();
        assert_eq!(latency.len(), mu * tau, "latency matrix shape");
        assert_eq!(platform_names.len(), mu);
        assert!(mu > 0 && tau > 0);
        ModelSet { mu, tau, latency, cost, n_sims, platform_names, families: Vec::new() }
    }

    /// Tag each task with its payoff family (one entry per task). Purely
    /// additive metadata: reporting and the per-family diagnostics use it;
    /// the objective reductions never look at it.
    pub fn with_task_families(mut self, families: Vec<Payoff>) -> ModelSet {
        assert_eq!(families.len(), self.tau, "one family per task");
        self.families = families;
        self
    }

    /// The payoff family of task `j`, when tagged.
    pub fn task_family(&self, j: usize) -> Option<Payoff> {
        self.families.get(j).copied()
    }

    /// Mean fitted β of `family`'s tasks on `platform` — `None` when the
    /// set is untagged or holds no task of that family. The per-family
    /// latency diagnostics compare this across families: on a fitted set a
    /// basket path should cost a multiple of a barrier path, which a
    /// single pooled line cannot express.
    pub fn family_beta(&self, platform: usize, family: Payoff) -> Option<f64> {
        let mut total = 0.0f64;
        let mut count = 0usize;
        for (j, f) in self.families.iter().enumerate() {
            if *f == family {
                total += self.model(platform, j).beta;
                count += 1;
            }
        }
        (count > 0).then(|| total / count as f64)
    }

    /// Nominal models straight from platform specs: β from application
    /// GFLOPS and the task's per-path FLOPs, γ from the spec's setup time.
    /// (The simulator's hidden factors make *fitted* models differ — that
    /// difference is exactly what Fig. 3 measures.)
    pub fn from_specs(specs: &[PlatformSpec], workload: &Workload) -> ModelSet {
        let mu = specs.len();
        let tau = workload.len();
        let mut latency = Vec::with_capacity(mu * tau);
        for s in specs {
            for t in &workload.tasks {
                let beta = t.flops_per_path() / (s.app_gflops.max(1e-9) * 1e9);
                latency.push(LatencyModel::new(beta, s.setup_secs));
            }
        }
        ModelSet::new(
            latency,
            specs.iter().map(|s| s.cost_model()).collect(),
            workload.tasks.iter().map(|t| t.n_sims).collect(),
            specs.iter().map(|s| s.name.clone()).collect(),
        )
        .with_task_families(workload.tasks.iter().map(|t| t.payoff).collect())
    }

    /// Expand a *per-type* model set into a *per-instance* one: `counts[t]`
    /// copies of type `t`'s latency rows and billing terms, instances named
    /// `type#k` (bare type name for a single instance). This is how the
    /// shape optimiser turns per-type fitted models into the per-instance
    /// rows the inner partitioners consume.
    pub fn replicate(&self, counts: &[usize]) -> Result<ModelSet> {
        if counts.len() != self.mu {
            return Err(CloudshapesError::config(format!(
                "composition has {} counts for {} platform types",
                counts.len(),
                self.mu
            )));
        }
        if counts.iter().all(|&c| c == 0) {
            return Err(CloudshapesError::config("composition rents no instances"));
        }
        let mut latency = Vec::new();
        let mut cost = Vec::new();
        let mut names = Vec::new();
        for (t, &count) in counts.iter().enumerate() {
            for k in 0..count {
                for j in 0..self.tau {
                    latency.push(*self.model(t, j));
                }
                cost.push(self.cost[t]);
                names.push(crate::platforms::spec::instance_name(
                    &self.platform_names[t],
                    k,
                    count,
                ));
            }
        }
        let mut set = ModelSet::new(latency, cost, self.n_sims.clone(), names);
        if !self.families.is_empty() {
            set = set.with_task_families(self.families.clone());
        }
        Ok(set)
    }

    pub fn model(&self, i: usize, j: usize) -> &LatencyModel {
        &self.latency[i * self.tau + j]
    }

    /// β·N — the full-task compute seconds of task `j` on platform `i`.
    pub fn work_secs(&self, i: usize, j: usize) -> f64 {
        self.model(i, j).beta * self.n_sims[j] as f64
    }

    /// γ of (i, j).
    pub fn setup_secs(&self, i: usize, j: usize) -> f64 {
        self.model(i, j).gamma
    }

    /// G_L(A)ᵢ: predicted latency of platform `i` under `alloc`.
    pub fn platform_latency(&self, alloc: &Allocation, i: usize) -> f64 {
        debug_assert_eq!(alloc.n_platforms(), self.mu);
        debug_assert_eq!(alloc.n_tasks(), self.tau);
        let mut total = 0.0;
        for j in 0..self.tau {
            let a = alloc.get(i, j);
            if a > ALLOC_TOL {
                total += self.work_secs(i, j) * a + self.setup_secs(i, j);
            }
        }
        total
    }

    /// F_L(A): the makespan.
    pub fn makespan(&self, alloc: &Allocation) -> f64 {
        (0..self.mu)
            .map(|i| self.platform_latency(alloc, i))
            .fold(0.0, f64::max)
    }

    /// G_C(A)ᵢ: billed cost of platform `i`.
    pub fn platform_cost(&self, alloc: &Allocation, i: usize) -> f64 {
        self.cost[i].cost(self.platform_latency(alloc, i))
    }

    /// F_C(A): total billed cost.
    pub fn total_cost(&self, alloc: &Allocation) -> f64 {
        (0..self.mu).map(|i| self.platform_cost(alloc, i)).sum()
    }

    /// Un-quantised total cost (LP lower bound).
    pub fn total_cost_relaxed(&self, alloc: &Allocation) -> f64 {
        (0..self.mu)
            .map(|i| self.cost[i].cost_relaxed(self.platform_latency(alloc, i)))
            .sum()
    }

    /// Latency of platform `i` running the ENTIRE workload alone — the
    /// "individual makespan" the paper's heuristic upper bound divides by.
    pub fn solo_latency(&self, i: usize) -> f64 {
        (0..self.tau)
            .map(|j| self.work_secs(i, j) + self.setup_secs(i, j))
            .sum()
    }

    /// Billed cost of platform `i` running the entire workload alone.
    pub fn solo_cost(&self, i: usize) -> f64 {
        self.cost[i].cost(self.solo_latency(i))
    }

    /// Both objectives at once (the evaluation the sweeps report).
    pub fn evaluate(&self, alloc: &Allocation) -> (f64, f64) {
        (self.makespan(alloc), self.total_cost(alloc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platforms::spec::small_cluster;
    use crate::workload::{generate, GeneratorConfig};

    pub(crate) fn toy_models() -> ModelSet {
        // 2 platforms x 2 tasks with hand-checkable numbers.
        // platform 0: beta 1e-3 (fast), gamma 10; platform 1: beta 4e-3, gamma 1.
        let l = |b, g| LatencyModel::new(b, g);
        ModelSet::new(
            vec![
                l(1e-3, 10.0), // p0, t0
                l(1e-3, 10.0), // p0, t1
                l(4e-3, 1.0),  // p1, t0
                l(4e-3, 1.0),  // p1, t1
            ],
            vec![CostModel::new(3600.0, 0.65).unwrap(), CostModel::new(60.0, 0.48).unwrap()],
            vec![100_000, 200_000],
            vec!["fast".into(), "cheapish".into()],
        )
    }

    #[test]
    fn replicate_expands_types_into_instances() {
        let types = toy_models();
        let m = types.replicate(&[2, 1]).unwrap();
        assert_eq!(m.mu, 3);
        assert_eq!(m.tau, 2);
        assert_eq!(m.platform_names, vec!["fast#0", "fast#1", "cheapish"]);
        for i in [0usize, 1] {
            for j in 0..2 {
                assert_eq!(m.model(i, j), types.model(0, j));
            }
            assert_eq!(m.cost[i], types.cost[0]);
        }
        assert_eq!(m.model(2, 0), types.model(1, 0));
        // Two instances halve the solo makespan's work term (setup repeats).
        let split = Allocation::proportional(3, 2, &[1.0, 1.0, 0.0]);
        let solo = Allocation::single_platform(3, 2, 0);
        assert!(m.makespan(&split) < m.makespan(&solo));
        // Degenerate compositions are typed errors.
        assert!(types.replicate(&[1]).is_err());
        assert!(types.replicate(&[0, 0]).is_err());
    }

    #[test]
    fn platform_latency_charges_setup_only_when_used() {
        let m = toy_models();
        let a = Allocation::single_platform(2, 2, 0);
        // p0: (1e-3*1e5 + 10) + (1e-3*2e5 + 10) = 110 + 210 = 320.
        assert!((m.platform_latency(&a, 0) - 320.0).abs() < 1e-9);
        assert_eq!(m.platform_latency(&a, 1), 0.0);
        assert!((m.makespan(&a) - 320.0).abs() < 1e-9);
    }

    #[test]
    fn fractional_allocation_scales_work_not_setup() {
        let m = toy_models();
        let mut a = Allocation::zero(2, 2);
        a.set(0, 0, 0.5);
        a.set(1, 0, 0.5);
        a.set(0, 1, 1.0);
        // p0: 0.5*100 + 10 + 200 + 10 = 270; p1: 0.5*400 + 1 = 201.
        assert!((m.platform_latency(&a, 0) - 270.0).abs() < 1e-9);
        assert!((m.platform_latency(&a, 1) - 201.0).abs() < 1e-9);
        assert!((m.makespan(&a) - 270.0).abs() < 1e-9);
    }

    #[test]
    fn costs_are_quantised() {
        let m = toy_models();
        let a = Allocation::single_platform(2, 2, 0);
        // 320 s on a 3600-s quantum -> 1 quantum -> $0.65.
        assert!((m.total_cost(&a) - 0.65).abs() < 1e-12);
        let b = Allocation::single_platform(2, 2, 1);
        // p1: 400+1 + 800+1 = 1202 s on 60-s quanta -> ceil(20.03) = 21
        // quanta -> 21 * 0.48/60h = 21 * 0.008 = $0.168.
        assert!((m.total_cost(&b) - 21.0 * 0.48 / 60.0).abs() < 1e-12);
    }

    #[test]
    fn relaxed_cost_lower_bounds_billed() {
        let m = toy_models();
        for alloc in [
            Allocation::single_platform(2, 2, 0),
            Allocation::single_platform(2, 2, 1),
            Allocation::proportional(2, 2, &[1.0, 1.0]),
        ] {
            assert!(m.total_cost_relaxed(&alloc) <= m.total_cost(&alloc) + 1e-12);
        }
    }

    #[test]
    fn solo_latency_matches_single_platform_makespan() {
        let m = toy_models();
        for i in 0..2 {
            let a = Allocation::single_platform(2, 2, i);
            assert!((m.solo_latency(i) - m.makespan(&a)).abs() < 1e-9);
        }
    }

    #[test]
    fn from_specs_builds_consistent_shapes() {
        let specs = small_cluster();
        let w = generate(&GeneratorConfig::small(5, 0.05, 1));
        let m = ModelSet::from_specs(&specs, &w);
        assert_eq!(m.mu, 3);
        assert_eq!(m.tau, 5);
        // A GPU beats a CPU on beta for every task.
        let gpu = specs.iter().position(|s| s.name == "gk104").unwrap();
        let cpu = specs.iter().position(|s| s.name == "xeon-e5-2660").unwrap();
        for j in 0..5 {
            assert!(m.model(gpu, j).beta < m.model(cpu, j).beta);
        }
    }

    #[test]
    fn family_tags_expose_per_family_betas() {
        let specs = small_cluster();
        let cfg = GeneratorConfig {
            payoff_mix: [0.0, 0.0, 0.5, 0.0, 0.5, 0.0],
            ..GeneratorConfig::small(24, 0.05, 3)
        };
        let w = generate(&cfg);
        let m = ModelSet::from_specs(&specs, &w);
        for (j, t) in w.tasks.iter().enumerate() {
            assert_eq!(m.task_family(j), Some(t.payoff));
        }
        // Nominal betas are flops/throughput, so the multi-asset basket's
        // mean beta must exceed the single-asset barrier's on every
        // platform — exactly the spread one pooled line cannot express.
        for i in 0..m.mu {
            let barrier = m.family_beta(i, Payoff::Barrier).unwrap();
            let basket = m.family_beta(i, Payoff::Basket).unwrap();
            assert!(basket > barrier, "platform {i}: {basket} vs {barrier}");
            assert!(m.family_beta(i, Payoff::Heston).is_none());
        }
        // Untagged sets answer None rather than lying.
        assert!(toy_models().family_beta(0, Payoff::European).is_none());
        assert_eq!(toy_models().task_family(0), None);
        // Replication preserves the tags.
        let r = m.replicate(&[1, 2, 0]).unwrap();
        assert_eq!(r.task_family(0), m.task_family(0));
    }

    #[test]
    fn splitting_beats_solo_on_makespan() {
        // Two platforms sharing work must not be slower than the best solo
        // run when setup times are small relative to work.
        let m = toy_models();
        let best_solo = (0..2).map(|i| m.solo_latency(i)).fold(f64::INFINITY, f64::min);
        // Split inversely proportional to beta.
        let a = Allocation::proportional(2, 2, &[1.0 / 1e-3, 1.0 / 4e-3]);
        assert!(m.makespan(&a) < best_solo);
    }
}
