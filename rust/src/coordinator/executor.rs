//! Execute an allocation on a cluster and measure what *actually* happens —
//! the "we then ran the resulting partitions on our experimental hardware"
//! step that produces the measured curves of Fig. 3.
//!
//! The executor is an **event-driven chunked scheduler**: every (platform,
//! task) slice of the allocation is split into bounded chunks
//! (counter-disjoint via u64 offsets), queued per platform, and driven by a
//! central event loop that
//!
//! - **retries failed chunks** with capped attempts, optionally re-homing
//!   them onto the platform with the earliest estimated finish — injected
//!   failures degrade statistics instead of zeroing prices;
//! - **rebalances stragglers**: when a lane's measured chunk latency drifts
//!   beyond a tolerance from its (fitted or nominal) latency model, queued
//!   chunks migrate from the lagging lane to the lane with the earliest
//!   estimated finish (model-guided work stealing);
//! - **survives spot preemption**: lanes whose spec carries a
//!   [`preemptible`](crate::platforms::PlatformSpec::preemptible) hazard
//!   draw a preemption time from it (seeded, deterministic); when a lane's
//!   virtual clock crosses it the lane dies — the in-flight chunk surfaces
//!   as a failed chunk for the retry machinery, queued chunks re-home onto
//!   live lanes, and the lane's bill covers only the quanta actually used
//!   up to the preemption;
//! - emits a typed [`ExecEvent`] stream (chunk done / failed / migrated,
//!   lane preempted, per-task [`PriceEstimate`]s) consumed by the serve
//!   protocol's `run`/`status` ops and the CLI `--watch` progress view;
//! - supports **epoch-bounded runs** ([`execute_epoch`]): dispatch halts
//!   once a lane's virtual clock crosses the epoch boundary, still-queued
//!   chunks are *deferred* (returned, not failed) and per-task path-counter
//!   bases keep successive epochs counter-disjoint — the hook the online
//!   scheduler ([`crate::coordinator::scheduler`]) re-plans allocations at.
//!
//! Each platform still executes its lane sequentially (latency accumulates
//! per lane; the realised makespan is the max lane time, realised cost
//! quantises each lane's total through the platform's billing terms).
//! **Equivalence guarantee:** with a noise-free simulator
//! ([`SimConfig::exact`](crate::platforms::SimConfig::exact)), rebalancing
//! disabled (or simply never triggered) and no failures, chunked execution
//! reproduces the one-shot path ([`execute_static`]) to ~1e-9: warm chunks
//! skip setup, the simulator budgets statistics per (platform, task) stream,
//! and per-task statistics merge in deterministic offset order.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::allocation::{Allocation, ALLOC_TOL};
use crate::coordinator::objectives::ModelSet;
use crate::obs::ExecCounters;
use crate::platforms::{ChunkCtx, Cluster};
use crate::pricing::mc::{combine, PayoffStats, PriceEstimate};
use crate::util::rng::Rng;
use crate::util::threadpool::parallel_map;
use crate::workload::Workload;

/// Per-platform execution record.
#[derive(Debug, Clone)]
pub struct PlatformReport {
    pub name: String,
    /// Total busy time on this platform's lane, seconds.
    pub latency_secs: f64,
    /// Billed quanta and cost.
    pub quanta: u64,
    pub cost: f64,
    /// Simulations dispatched here (failed attempts and retries re-count).
    pub sims: u64,
    pub errors: Vec<String>,
}

/// Whole-run execution record.
#[derive(Debug)]
pub struct ExecutionReport {
    /// Realised makespan (max platform latency), seconds.
    pub makespan_secs: f64,
    /// Realised total billed cost, $.
    pub cost: f64,
    pub platforms: Vec<PlatformReport>,
    /// Discounted price estimate per task (None if every slice failed).
    pub prices: Vec<Option<PriceEstimate>>,
    /// Chunks that exhausted their retry budget (permanently failed).
    pub failures: usize,
    /// Chunk executions that completed successfully.
    pub chunks: usize,
    /// Failed chunk executions that were re-queued.
    pub retries: usize,
    /// Queued chunks migrated off straggling lanes (including off preempted
    /// ones).
    pub migrations: usize,
    /// Spot lanes that were preempted mid-run.
    pub preemptions: usize,
}

/// Chunk retry policy.
#[derive(Debug, Clone)]
pub struct RetryConfig {
    /// Total execution attempts per chunk (1 = today's no-retry reporting:
    /// the first failure is final).
    pub max_attempts: u32,
    /// Re-home retried chunks onto the platform with the earliest estimated
    /// finish instead of insisting on the platform that failed.
    pub rehome: bool,
}

impl Default for RetryConfig {
    fn default() -> Self {
        RetryConfig { max_attempts: 3, rehome: true }
    }
}

/// Straggler rebalancing policy (model-guided work stealing).
#[derive(Debug, Clone)]
pub struct RebalanceConfig {
    pub enabled: bool,
    /// Relative drift of measured chunk latency over the model prediction
    /// that marks a lane as straggling (0.25 = 25% slower than modelled).
    pub tolerance: f64,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig { enabled: true, tolerance: 0.25 }
    }
}

/// Execution controls.
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    pub seed: u32,
    /// Worker threads dispatching chunks (shared knob with the solver's
    /// `workers`; clamped to the cluster size — each platform's lane is
    /// sequential regardless).
    pub workers: usize,
    /// Max simulations per chunk (0 = unchunked: one chunk per slice).
    pub chunk_sims: u64,
    pub retry: RetryConfig,
    pub rebalance: RebalanceConfig,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig {
            seed: 1,
            workers: 16,
            chunk_sims: 1 << 24,
            retry: RetryConfig::default(),
            rebalance: RebalanceConfig::default(),
        }
    }
}

/// One event of a chunked execution, emitted by the scheduler's event loop
/// (always on the caller's thread) as the run progresses.
#[derive(Debug, Clone)]
pub enum ExecEvent {
    /// Scheduling is done; execution starts.
    Started { chunks: usize, tasks: usize },
    ChunkDone {
        platform: usize,
        task: usize,
        offset: u64,
        n: u64,
        latency_secs: f64,
        /// First chunk of this (platform, task) stream: its latency includes
        /// the per-stream setup γ (re-fit consumers subtract it).
        cold: bool,
        /// Chunks completed so far / total scheduled.
        done: usize,
        total: usize,
    },
    ChunkFailed {
        platform: usize,
        task: usize,
        offset: u64,
        n: u64,
        /// 1-based attempt number that just failed.
        attempt: u32,
        error: String,
        will_retry: bool,
        /// Platform the retry was queued on, when different from `platform`.
        rehomed_to: Option<usize>,
    },
    /// A queued chunk moved off a straggling lane.
    ChunkMigrated { from: usize, to: usize, task: usize, offset: u64, n: u64 },
    /// A spot lane crossed its preemption time and died. Its in-flight
    /// chunk fails (retry machinery applies), `drained` queued chunks were
    /// re-homed onto live lanes, and the lane bills only up to `at_secs`.
    LanePreempted { platform: usize, at_secs: f64, drained: usize },
    /// Every chunk of `task` has resolved; `partial` when some of its
    /// chunks permanently failed (the estimate covers the surviving paths).
    TaskPriced { task: usize, estimate: PriceEstimate, partial: bool },
    Finished { makespan_secs: f64, cost: f64, failures: usize },
}

/// A unit of schedulable work: `n` simulations of `task` starting at the
/// global path counter `offset`.
#[derive(Debug, Clone, Copy)]
struct Chunk {
    task: usize,
    offset: u64,
    n: u64,
    /// Completed attempts (0 on first dispatch).
    attempt: u32,
}

/// One platform's scheduler lane.
struct Lane {
    queue: VecDeque<Chunk>,
    busy: bool,
    /// Preempted spot lane: never claimed again, queue drained at death.
    dead: bool,
    /// Accumulated lane latency, seconds (virtual for simulated platforms,
    /// wall-clock for native ones).
    time: f64,
    sims: u64,
    errors: Vec<String>,
    /// Per-task simulations successfully completed on this lane — the
    /// [`ChunkCtx::prior_sims`] hint.
    done_sims: Vec<u64>,
    /// Model-estimated seconds of queued work.
    queued_secs: f64,
    /// EWMA of measured/predicted chunk latency (1.0 = on-model).
    drift: f64,
    drift_obs: u64,
}

struct Sched {
    lanes: Vec<Lane>,
    /// Chunks not yet terminally resolved (done or permanently failed).
    outstanding: usize,
    done: bool,
}

/// Raw completion record a worker posts to the event loop.
struct Completion {
    platform: usize,
    chunk: Chunk,
    latency_secs: f64,
    /// The chunk ran with `prior_sims == 0` (setup was paid).
    cold: bool,
    stats: Option<PayoffStats>,
    error: Option<String>,
    /// This completion crossed the lane's preemption time: the lane is now
    /// dead and billed only up to `at_secs`.
    preempted: Option<PreemptionNotice>,
}

/// What a preemption did to the dying lane's queue.
struct PreemptionNotice {
    at_secs: f64,
    /// Queued chunks re-homed onto live lanes: (destination, chunk).
    moved: Vec<(usize, Chunk)>,
    /// Queued chunks with no live lane left — permanently failed.
    orphaned: Vec<Chunk>,
}

/// Per-(platform, task) latency coefficients the scheduler estimates with:
/// fitted models when available, nominal spec-derived ones otherwise.
struct Coeffs {
    mu: usize,
    tau: usize,
    beta: Vec<f64>,
    gamma: Vec<f64>,
}

impl Coeffs {
    fn build(cluster: &Cluster, workload: &Workload, models: Option<&ModelSet>) -> Coeffs {
        let (mu, tau) = (cluster.len(), workload.len());
        let mut beta = Vec::with_capacity(mu * tau);
        let mut gamma = Vec::with_capacity(mu * tau);
        if let Some(m) = models {
            for i in 0..mu {
                for j in 0..tau {
                    beta.push(m.model(i, j).beta);
                    gamma.push(m.model(i, j).gamma);
                }
            }
        } else {
            for spec in cluster.specs() {
                for t in &workload.tasks {
                    beta.push(t.flops_per_path() / (spec.app_gflops.max(1e-9) * 1e9));
                    gamma.push(spec.setup_secs);
                }
            }
        }
        Coeffs { mu, tau, beta, gamma }
    }

    /// Predicted seconds of a chunk on platform `i` (work only — setup is
    /// charged per stream, not per chunk).
    fn est(&self, i: usize, c: &Chunk) -> f64 {
        debug_assert!(i < self.mu && c.task < self.tau);
        self.beta[i * self.tau + c.task] * c.n as f64
    }

    fn predicted(&self, i: usize, c: &Chunk, cold: bool) -> f64 {
        self.est(i, c) + if cold { self.gamma[i * self.tau + c.task] } else { 0.0 }
    }
}

fn check_shapes(cluster: &Cluster, workload: &Workload, alloc: &Allocation) -> Result<()> {
    alloc.validate()?;
    workload.validate()?;
    if alloc.n_platforms() != cluster.len() || alloc.n_tasks() != workload.len() {
        return Err(CloudshapesError::runtime(format!(
            "allocation shape {}x{} vs cluster {} / workload {}",
            alloc.n_platforms(),
            alloc.n_tasks(),
            cluster.len(),
            workload.len()
        )));
    }
    Ok(())
}

/// Integer-split every task's path space across platforms and compute the
/// per-slice u64 counter offsets (prefix sums keep slices disjoint; at
/// `n_sims` up to `1 << 34` these must NOT be truncated to 32 bits).
/// `bases` shifts each task's offsets — epoch runs pass the task's global
/// path-counter cursor so successive epochs never overlap counter ranges.
fn slice_layout(
    workload: &Workload,
    alloc: &Allocation,
    bases: Option<&[u64]>,
) -> (Vec<Vec<u64>>, Vec<Vec<u64>>) {
    let splits: Vec<Vec<u64>> = (0..workload.len())
        .map(|j| alloc.split_sims(j, workload.tasks[j].n_sims))
        .collect();
    let offsets: Vec<Vec<u64>> = splits
        .iter()
        .enumerate()
        .map(|(j, row)| {
            let mut acc = bases.map_or(0, |b| b[j]);
            row.iter()
                .map(|n| {
                    let o = acc;
                    acc += n;
                    o
                })
                .collect()
        })
        .collect();
    (splits, offsets)
}

/// Run `alloc` for `workload` on `cluster` with the chunked event-driven
/// scheduler (no event observer, scheduler-estimated nominal models).
pub fn execute(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
) -> Result<ExecutionReport> {
    execute_with(cluster, workload, alloc, cfg, None, &mut |_| {})
}

/// As [`execute`], with fitted `models` guiding straggler detection and an
/// `on_event` observer receiving the [`ExecEvent`] stream (called on the
/// caller's thread).
pub fn execute_with(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
    models: Option<&ModelSet>,
    on_event: &mut dyn FnMut(&ExecEvent),
) -> Result<ExecutionReport> {
    let counters = ExecCounters::default();
    execute_shared(cluster, workload, alloc, cfg, models, &counters, on_event)
}

/// As [`execute_with`], tallying into a caller-owned [`ExecCounters`] —
/// the ONE retry/migration/preemption count of the run. The returned
/// report's counter fields are deltas over `counters`' entry values, so a
/// live view holding the same counters (the session's `status` op) and the
/// final report always agree.
pub fn execute_shared(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
    models: Option<&ModelSet>,
    counters: &ExecCounters,
    on_event: &mut dyn FnMut(&ExecEvent),
) -> Result<ExecutionReport> {
    run_chunked(cluster, workload, alloc, cfg, models, None, None, counters, on_event)
        .map(|o| o.report)
}

/// One epoch boundary of an online run — the knobs [`execute_epoch`] adds
/// on top of [`execute_with`].
#[derive(Debug, Clone, Copy)]
pub struct EpochCtx<'a> {
    /// Lane-virtual seconds after which no further chunk is dispatched.
    /// In-flight chunks still finish, so the boundary is soft by at most
    /// one chunk per lane.
    pub halt_secs: f64,
    /// Per-task global path-counter bases: this epoch's slices start at
    /// `base_offsets[j]`, keeping successive epochs counter-disjoint.
    pub base_offsets: &'a [u64],
}

/// What one epoch of chunked execution accomplished.
#[derive(Debug)]
pub struct EpochReport {
    /// The epoch's execution record. Its `prices` cover only the paths that
    /// completed *this epoch* — merge [`stats`](Self::stats) across epochs
    /// for whole-job estimates.
    pub exec: ExecutionReport,
    /// Per-task simulations successfully completed this epoch.
    pub done_sims: Vec<u64>,
    /// Per-task merged raw payoff statistics of this epoch's completed
    /// chunks (offset-ordered, so deterministic) — mergeable across epochs.
    pub stats: Vec<PayoffStats>,
    /// Per-task simulations still queued when the boundary hit: never
    /// dispatched, never failed — re-plan them next epoch.
    pub deferred_sims: Vec<u64>,
}

/// Run one *epoch* of `alloc`: chunked execution that stops dispatching
/// once a lane's virtual clock crosses [`EpochCtx::halt_secs`]. Queued
/// chunks left behind are **deferred** (reported per task, not failed), and
/// [`EpochCtx::base_offsets`] shifts every task's path counters so repeated
/// epochs draw disjoint Monte Carlo paths. This is the epoch-boundary
/// reallocation hook the online scheduler
/// ([`crate::coordinator::scheduler::OnlineScheduler`]) is built on: plan →
/// run an epoch → observe → re-plan.
pub fn execute_epoch(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
    models: Option<&ModelSet>,
    epoch: EpochCtx<'_>,
    on_event: &mut dyn FnMut(&ExecEvent),
) -> Result<EpochReport> {
    if !(epoch.halt_secs > 0.0 && epoch.halt_secs.is_finite()) {
        return Err(CloudshapesError::runtime(format!(
            "epoch halt_secs must be positive and finite, got {}",
            epoch.halt_secs
        )));
    }
    if epoch.base_offsets.len() != workload.len() {
        return Err(CloudshapesError::runtime(format!(
            "epoch base_offsets has {} entries for {} tasks",
            epoch.base_offsets.len(),
            workload.len()
        )));
    }
    let counters = ExecCounters::default();
    run_chunked(
        cluster,
        workload,
        alloc,
        cfg,
        models,
        Some(epoch.halt_secs),
        Some(epoch.base_offsets),
        &counters,
        on_event,
    )
    .map(|o| EpochReport {
        exec: o.report,
        done_sims: o.done_sims,
        stats: o.merged_stats,
        deferred_sims: o.deferred_sims,
    })
}

/// Everything one chunked run produces; the epoch path consumes the extra
/// per-task accounting, the plain path keeps only the report.
struct ChunkedOutcome {
    report: ExecutionReport,
    done_sims: Vec<u64>,
    merged_stats: Vec<PayoffStats>,
    deferred_sims: Vec<u64>,
}

/// The shared chunked event loop behind [`execute_with`] (no halt, zero
/// bases) and [`execute_epoch`] (halt + counter bases).
#[allow(clippy::too_many_arguments)]
fn run_chunked(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
    models: Option<&ModelSet>,
    halt_secs: Option<f64>,
    base_offsets: Option<&[u64]>,
    counters: &ExecCounters,
    on_event: &mut dyn FnMut(&ExecEvent),
) -> Result<ChunkedOutcome> {
    // Entry snapshot: the report covers this run even if the caller reuses
    // one counters tally across runs.
    let base = (
        counters.chunks(),
        counters.retries(),
        counters.migrations(),
        counters.preemptions(),
        counters.failures(),
    );
    check_shapes(cluster, workload, alloc)?;
    let (mu, tau) = (cluster.len(), workload.len());
    let (splits, offsets) = slice_layout(workload, alloc, base_offsets);
    let coeffs = Coeffs::build(cluster, workload, models);

    // Build per-platform chunk queues: slices in task order (matching the
    // one-shot path), each split into at most `chunk_sims`-path chunks.
    let chunk_cap = if cfg.chunk_sims == 0 { u64::MAX } else { cfg.chunk_sims };
    let mut lanes: Vec<Lane> = (0..mu)
        .map(|_| Lane {
            queue: VecDeque::new(),
            busy: false,
            dead: false,
            time: 0.0,
            sims: 0,
            errors: Vec::new(),
            done_sims: vec![0; tau],
            queued_secs: 0.0,
            drift: 1.0,
            drift_obs: 0,
        })
        .collect();
    // Spot scenario: each preemptible lane draws its preemption time (in
    // lane-virtual seconds) from the spec's exponential hazard — a pure
    // function of (seed, lane), so runs are reproducible.
    let specs = cluster.specs();
    let preempt_at: Vec<Option<f64>> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| {
            s.preemptible.map(|per_hour| {
                let mut rng = Rng::new(
                    (cfg.seed as u64 ^ ((i as u64) << 32))
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        ^ 0x5057,
                );
                3600.0 * -(1.0 - rng.f64()).ln() / per_hour
            })
        })
        .collect();
    let mut total_chunks = 0usize;
    let mut chunks_per_task = vec![0usize; tau];
    for j in 0..tau {
        for (i, lane) in lanes.iter_mut().enumerate() {
            let n_slice = splits[j][i];
            if n_slice == 0 || alloc.get(i, j) <= ALLOC_TOL {
                continue;
            }
            let mut offset = offsets[j][i];
            let mut remaining = n_slice;
            while remaining > 0 {
                let n = remaining.min(chunk_cap);
                let chunk = Chunk { task: j, offset, n, attempt: 0 };
                lane.queued_secs += coeffs.est(i, &chunk);
                lane.queue.push_back(chunk);
                offset += n;
                remaining -= n;
                total_chunks += 1;
                chunks_per_task[j] += 1;
            }
        }
    }
    on_event(&ExecEvent::Started { chunks: total_chunks, tasks: tau });

    let sched = Mutex::new(Sched { lanes, outstanding: total_chunks, done: total_chunks == 0 });
    let available = Condvar::new();
    let (tx, rx) = mpsc::channel::<Completion>();

    // Per-task resolution state, owned by the event loop.
    let mut chunk_stats: Vec<Vec<(u64, PayoffStats)>> = vec![Vec::new(); tau];
    let mut remaining_chunks = chunks_per_task;
    let mut task_failures = vec![0usize; tau];
    let mut prices: Vec<Option<PriceEstimate>> = vec![None; tau];
    // done_count/failures stay local because the loop's termination
    // condition reads them; every externally visible tally goes through the
    // shared `counters` (the single source the report and any live status
    // view both read).
    let (mut done_count, mut failures) = (0usize, 0usize);
    // Epoch runs: chunks still queued once no lane can dispatch any more
    // (every lane idle and past the boundary, dead, or empty) are deferred
    // to the next epoch instead of executed.
    let mut deferred: Vec<Chunk> = Vec::new();

    let workers = cfg.workers.max(1).min(mu);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let (sched, available, tx) = (&sched, &available, tx.clone());
            let (cluster, workload, coeffs, seed) = (cluster, workload, &coeffs, cfg.seed);
            let (preempt_at, specs) = (&preempt_at, &specs);
            scope.spawn(move || loop {
                // Claim the earliest-in-time idle lane with queued work —
                // the event-driven dispatch order. The busy flag keeps each
                // lane sequential no matter the worker count; dead (spot
                // preempted) lanes are never claimed, nor — in epoch runs —
                // are lanes whose clock crossed the epoch boundary.
                let claimed = {
                    let mut g = sched.lock().unwrap();
                    loop {
                        if g.done {
                            return;
                        }
                        let pick = (0..g.lanes.len())
                            .filter(|&i| {
                                let l = &g.lanes[i];
                                !l.busy
                                    && !l.dead
                                    && !l.queue.is_empty()
                                    && halt_secs.map_or(true, |h| l.time < h)
                            })
                            .min_by(|&a, &b| g.lanes[a].time.total_cmp(&g.lanes[b].time));
                        if let Some(i) = pick {
                            let chunk = g.lanes[i].queue.pop_front().unwrap();
                            g.lanes[i].busy = true;
                            g.lanes[i].queued_secs =
                                (g.lanes[i].queued_secs - coeffs.est(i, &chunk)).max(0.0);
                            let prior = g.lanes[i].done_sims[chunk.task];
                            break (i, chunk, prior);
                        }
                        g = available.wait(g).unwrap();
                    }
                };
                let (i, chunk, prior) = claimed;
                let task = &workload.tasks[chunk.task];
                // A panicking platform must not wedge the scheduler (the
                // lock is NOT held here, so no poisoning): surface the
                // panic as a failed chunk and let the retry policy decide.
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    cluster.platform(i).execute(
                        task,
                        chunk.n,
                        seed,
                        ChunkCtx { offset: chunk.offset, prior_sims: prior },
                    )
                }))
                .unwrap_or_else(|_| crate::platforms::ExecOutcome {
                    latency_secs: 0.0,
                    stats: None,
                    error: Some(format!("platform {i}: panicked executing a chunk")),
                });
                let mut out = out;
                let mut preempted = None;
                {
                    let mut g = sched.lock().unwrap();
                    // Spot preemption: the lane's virtual clock crossing its
                    // drawn preemption time kills the lane. The crossing
                    // chunk's work is lost (failure), the bill stops at the
                    // preemption time, and queued chunks re-home now —
                    // under this same lock, so no worker can claim them in
                    // between.
                    let crossed = preempt_at[i]
                        .map(|p| !g.lanes[i].dead && g.lanes[i].time + out.latency_secs > p)
                        .unwrap_or(false);
                    if crossed {
                        let at = preempt_at[i].unwrap();
                        let lane = &mut g.lanes[i];
                        lane.dead = true;
                        lane.time = at;
                        lane.sims += chunk.n;
                        lane.busy = false;
                        lane.queued_secs = 0.0;
                        let err = format!(
                            "{}: spot instance preempted after {at:.1}s",
                            specs[i].name
                        );
                        lane.errors.push(err.clone());
                        out.stats = None;
                        out.error = Some(err);
                        let queued: Vec<Chunk> = lane.queue.drain(..).collect();
                        let mut moved = Vec::new();
                        let mut orphaned = Vec::new();
                        for c in queued {
                            match earliest_finish_lane(&g.lanes, coeffs, &c, Some(i)) {
                                Some(t) => {
                                    g.lanes[t].queued_secs += coeffs.est(t, &c);
                                    g.lanes[t].queue.push_back(c);
                                    moved.push((t, c));
                                }
                                None => orphaned.push(c),
                            }
                        }
                        preempted = Some(PreemptionNotice { at_secs: at, moved, orphaned });
                    } else {
                        let lane = &mut g.lanes[i];
                        lane.time += out.latency_secs;
                        lane.sims += chunk.n;
                        lane.busy = false;
                        if out.stats.is_some() {
                            lane.done_sims[chunk.task] += chunk.n;
                            // Straggler signal: measured vs modelled chunk
                            // latency (failures carry no throughput signal —
                            // their cheap setup-only latency would make a
                            // broken lane look fast).
                            let predicted = coeffs.predicted(i, &chunk, prior == 0).max(1e-12);
                            let ratio = out.latency_secs / predicted;
                            lane.drift = if lane.drift_obs == 0 {
                                ratio
                            } else {
                                0.5 * lane.drift + 0.5 * ratio
                            };
                            lane.drift_obs += 1;
                        } else if let Some(e) = &out.error {
                            lane.errors.push(e.clone());
                        }
                    }
                }
                available.notify_all();
                let _ = tx.send(Completion {
                    platform: i,
                    chunk,
                    latency_secs: out.latency_secs,
                    cold: prior == 0,
                    stats: out.stats,
                    error: out.error,
                    preempted,
                });
            });
        }
        drop(tx);

        // The central event loop: price tasks as they complete, retry and
        // re-home failures, migrate queued work off stragglers, defer
        // work stranded behind an epoch boundary. (No upfront drain is
        // needed: halt_secs is validated positive and every lane starts at
        // time 0, so work can only strand after a completion — where the
        // per-iteration drain below runs.)
        while done_count + failures + deferred.len() < total_chunks {
            let ev = rx.recv().expect("all workers exited with chunks outstanding");
            let Completion { platform, chunk, latency_secs, cold, stats, error, preempted } = ev;
            if let Some(notice) = preempted {
                counters.add_preemption();
                on_event(&ExecEvent::LanePreempted {
                    platform,
                    at_secs: notice.at_secs,
                    // Only chunks that actually found a live lane: orphaned
                    // ones surface as the ChunkFailed events below, so a
                    // stream consumer never mistakes lost work for saved.
                    drained: notice.moved.len(),
                });
                for (to, c) in &notice.moved {
                    counters.add_migration();
                    on_event(&ExecEvent::ChunkMigrated {
                        from: platform,
                        to: *to,
                        task: c.task,
                        offset: c.offset,
                        n: c.n,
                    });
                }
                // Queued chunks with no live lane left fail permanently.
                for c in notice.orphaned {
                    failures += 1;
                    counters.add_failure();
                    task_failures[c.task] += 1;
                    resolve_chunk(&sched, &available);
                    on_event(&ExecEvent::ChunkFailed {
                        platform,
                        task: c.task,
                        offset: c.offset,
                        n: c.n,
                        // 1-based like every ChunkFailed: the orphaning
                        // counts as the attempt that failed (it never ran).
                        attempt: c.attempt + 1,
                        error: "spot preemption: no live lanes left".to_string(),
                        will_retry: false,
                        rehomed_to: None,
                    });
                    remaining_chunks[c.task] -= 1;
                    if remaining_chunks[c.task] == 0 {
                        price_task(
                            c.task,
                            workload,
                            &mut chunk_stats,
                            &task_failures,
                            &mut prices,
                            on_event,
                        );
                    }
                }
            }
            match (stats, error) {
                (Some(s), _) => {
                    done_count += 1;
                    counters.add_chunk();
                    if s.n > 0 {
                        chunk_stats[chunk.task].push((chunk.offset, s));
                    }
                    resolve_chunk(&sched, &available);
                    on_event(&ExecEvent::ChunkDone {
                        platform,
                        task: chunk.task,
                        offset: chunk.offset,
                        n: chunk.n,
                        latency_secs,
                        cold,
                        done: done_count,
                        total: total_chunks,
                    });
                    remaining_chunks[chunk.task] -= 1;
                    if remaining_chunks[chunk.task] == 0 {
                        price_task(
                            chunk.task,
                            workload,
                            &mut chunk_stats,
                            &task_failures,
                            &mut prices,
                            on_event,
                        );
                    }
                    if cfg.rebalance.enabled {
                        if let Some(mv) =
                            try_rebalance(&sched, &coeffs, cfg.rebalance.tolerance)
                        {
                            counters.add_migration();
                            available.notify_all();
                            on_event(&mv);
                        }
                    }
                }
                (None, error) => {
                    let error = error.unwrap_or_else(|| "unknown".into());
                    let attempt = chunk.attempt + 1;
                    let mut will_retry = attempt < cfg.retry.max_attempts;
                    let mut rehomed_to = None;
                    if will_retry {
                        let mut g = sched.lock().unwrap();
                        // A dead (preempted) lane can never run the retry:
                        // re-home regardless of the rehome flag. With no
                        // live lane left the chunk fails permanently.
                        let target = if cfg.retry.rehome || g.lanes[platform].dead {
                            // Prefer any live lane but the one that failed.
                            earliest_finish_lane(&g.lanes, &coeffs, &chunk, Some(platform))
                        } else {
                            Some(platform)
                        };
                        match target {
                            Some(t) => {
                                counters.add_retry();
                                if t != platform {
                                    rehomed_to = Some(t);
                                }
                                let retry = Chunk { attempt, ..chunk };
                                g.lanes[t].queued_secs += coeffs.est(t, &retry);
                                g.lanes[t].queue.push_back(retry);
                                drop(g);
                                available.notify_all();
                            }
                            None => will_retry = false,
                        }
                    }
                    if !will_retry {
                        failures += 1;
                        counters.add_failure();
                        task_failures[chunk.task] += 1;
                        resolve_chunk(&sched, &available);
                    }
                    on_event(&ExecEvent::ChunkFailed {
                        platform,
                        task: chunk.task,
                        offset: chunk.offset,
                        n: chunk.n,
                        attempt,
                        error,
                        will_retry,
                        rehomed_to,
                    });
                    if !will_retry {
                        remaining_chunks[chunk.task] -= 1;
                        if remaining_chunks[chunk.task] == 0 {
                            price_task(
                                chunk.task,
                                workload,
                                &mut chunk_stats,
                                &task_failures,
                                &mut prices,
                                on_event,
                            );
                        }
                    }
                }
            }
            if let Some(h) = halt_secs {
                // Epoch boundary: once nothing is in flight and no lane can
                // dispatch, everything still queued is deferred.
                drain_stranded(&sched, &available, h, &mut deferred);
            }
        }
        // All chunks resolved (the last resolve_chunk set `done`); wake any
        // still-waiting workers so the scope can join them.
        available.notify_all();
    });

    let g = sched.into_inner().unwrap();
    let mut platforms = Vec::with_capacity(mu);
    let mut done_sims = vec![0u64; tau];
    for (i, lane) in g.lanes.iter().enumerate() {
        let cm = specs[i].cost_model();
        platforms.push(PlatformReport {
            name: specs[i].name.clone(),
            latency_secs: lane.time,
            quanta: cm.quanta(lane.time),
            cost: cm.cost(lane.time),
            sims: lane.sims,
            errors: lane.errors.clone(),
        });
        for j in 0..tau {
            done_sims[j] += lane.done_sims[j];
        }
    }
    // Deterministic per-task merges over everything that completed: used
    // both for the epoch accounting and to price tasks the epoch boundary
    // (or permanent failures) left partially done.
    let mut merged_stats = Vec::with_capacity(tau);
    for (j, t) in workload.tasks.iter().enumerate() {
        let merged = fold_chunk_stats(&mut chunk_stats[j]);
        if merged.n > 0 && prices[j].is_none() {
            prices[j] = Some(combine(&merged, t.discount()));
        }
        merged_stats.push(merged);
    }
    let mut deferred_sims = vec![0u64; tau];
    for c in &deferred {
        deferred_sims[c.task] += c.n;
    }
    let makespan_secs = platforms.iter().map(|p| p.latency_secs).fold(0.0f64, f64::max);
    let cost = platforms.iter().map(|p| p.cost).sum();
    on_event(&ExecEvent::Finished { makespan_secs, cost, failures });
    Ok(ChunkedOutcome {
        report: ExecutionReport {
            makespan_secs,
            cost,
            platforms,
            prices,
            failures: counters.failures() - base.4,
            chunks: counters.chunks() - base.0,
            retries: counters.retries() - base.1,
            migrations: counters.migrations() - base.2,
            preemptions: counters.preemptions() - base.3,
        },
        done_sims,
        merged_stats,
        deferred_sims,
    })
}

/// Epoch-boundary drain: when no chunk is in flight and no lane can
/// dispatch (each is dead, past `halt`, or out of work), move everything
/// still queued into `deferred` and resolve the run.
fn drain_stranded(
    sched: &Mutex<Sched>,
    available: &Condvar,
    halt: f64,
    deferred: &mut Vec<Chunk>,
) {
    let mut g = sched.lock().unwrap();
    if g.done || g.lanes.iter().any(|l| l.busy) {
        return;
    }
    if g.lanes.iter().any(|l| !l.dead && l.time < halt && !l.queue.is_empty()) {
        return;
    }
    let mut n = 0usize;
    for lane in g.lanes.iter_mut() {
        n += lane.queue.len();
        deferred.extend(lane.queue.drain(..));
        lane.queued_secs = 0.0;
    }
    if n == 0 {
        return;
    }
    g.outstanding -= n;
    if g.outstanding == 0 {
        g.done = true;
        drop(g);
        available.notify_all();
    }
}

/// Mark one chunk terminally resolved; flips the scheduler to done (waking
/// every worker) when it was the last.
fn resolve_chunk(sched: &Mutex<Sched>, available: &Condvar) {
    let mut g = sched.lock().unwrap();
    g.outstanding -= 1;
    if g.outstanding == 0 {
        g.done = true;
        drop(g);
        available.notify_all();
    }
}

/// Deterministic merge of one task's chunk statistics: sorted by offset
/// (so scheduling order never changes the float association), fold-merged,
/// discounted. None when no paths survived. BOTH executor paths price
/// through this single kernel — the 1e-9 chunked-vs-static equivalence
/// depends on them merging identically.
fn merge_chunk_stats(
    stats: &mut [(u64, PayoffStats)],
    discount: f64,
) -> Option<PriceEstimate> {
    let merged = fold_chunk_stats(stats);
    if merged.n > 0 {
        Some(combine(&merged, discount))
    } else {
        None
    }
}

/// Offset-ordered fold of one task's chunk statistics — the deterministic
/// merge both pricing and the epoch accounting share.
fn fold_chunk_stats(stats: &mut [(u64, PayoffStats)]) -> PayoffStats {
    stats.sort_by_key(|(offset, _)| *offset);
    stats.iter().fold(PayoffStats::default(), |acc, (_, s)| acc.merge(s))
}

/// Price a completed task and emit its [`ExecEvent::TaskPriced`] event.
fn price_task(
    task: usize,
    workload: &Workload,
    chunk_stats: &mut [Vec<(u64, PayoffStats)>],
    task_failures: &[usize],
    prices: &mut [Option<PriceEstimate>],
    on_event: &mut dyn FnMut(&ExecEvent),
) {
    let Some(estimate) =
        merge_chunk_stats(&mut chunk_stats[task], workload.tasks[task].discount())
    else {
        return; // every slice failed: no estimate
    };
    prices[task] = Some(estimate);
    on_event(&ExecEvent::TaskPriced { task, estimate, partial: task_failures[task] > 0 });
}

/// Live lane with the earliest drift-scaled estimated finish for `chunk`;
/// `avoid` (the lane a failure was just observed on) is excluded whenever a
/// live alternative exists. `None` when every lane is dead.
fn earliest_finish_lane(
    lanes: &[Lane],
    coeffs: &Coeffs,
    chunk: &Chunk,
    avoid: Option<usize>,
) -> Option<usize> {
    let live: Vec<usize> = (0..lanes.len()).filter(|&i| !lanes[i].dead).collect();
    let candidates: Vec<usize> = match avoid {
        Some(a) if live.iter().any(|&i| i != a) => {
            live.into_iter().filter(|&i| i != a).collect()
        }
        _ => live,
    };
    candidates.into_iter().min_by(|&a, &b| {
        let fa = lane_finish(&lanes[a]) + coeffs.est(a, chunk) * lanes[a].drift;
        let fb = lane_finish(&lanes[b]) + coeffs.est(b, chunk) * lanes[b].drift;
        fa.total_cmp(&fb)
    })
}

fn lane_finish(lane: &Lane) -> f64 {
    lane.time + lane.queued_secs * lane.drift
}

/// Model-guided work stealing: move the tail chunk of the worst straggling
/// lane (measured drift beyond tolerance, work still queued) to the lane
/// with the earliest estimated finish — but only when that actually helps.
fn try_rebalance(
    sched: &Mutex<Sched>,
    coeffs: &Coeffs,
    tolerance: f64,
) -> Option<ExecEvent> {
    let mut g = sched.lock().unwrap();
    let laggard = (0..g.lanes.len())
        .filter(|&i| {
            let l = &g.lanes[i];
            l.drift_obs > 0 && l.drift > 1.0 + tolerance && !l.queue.is_empty()
        })
        .max_by(|&a, &b| lane_finish(&g.lanes[a]).total_cmp(&lane_finish(&g.lanes[b])))?;
    let target = (0..g.lanes.len())
        .filter(|&i| i != laggard && !g.lanes[i].dead)
        .min_by(|&a, &b| lane_finish(&g.lanes[a]).total_cmp(&lane_finish(&g.lanes[b])))?;
    let chunk = *g.lanes[laggard].queue.back().unwrap();
    let gain_ok = lane_finish(&g.lanes[target]) + coeffs.est(target, &chunk) * g.lanes[target].drift
        < lane_finish(&g.lanes[laggard]);
    if !gain_ok {
        return None;
    }
    g.lanes[laggard].queue.pop_back();
    g.lanes[laggard].queued_secs =
        (g.lanes[laggard].queued_secs - coeffs.est(laggard, &chunk)).max(0.0);
    g.lanes[target].queued_secs += coeffs.est(target, &chunk);
    g.lanes[target].queue.push_back(chunk);
    Some(ExecEvent::ChunkMigrated {
        from: laggard,
        to: target,
        task: chunk.task,
        offset: chunk.offset,
        n: chunk.n,
    })
}

/// The pre-chunking one-shot path: every (platform, task) slice executes as
/// a single call, platforms run in parallel. Kept as the equivalence
/// baseline (`benches/perf_executor.rs`, `tests/executor_chunked.rs`) — the
/// chunked scheduler must reproduce this report under a noise-free
/// simulator with rebalancing off. The spot-preemption scenario exists only
/// on the chunked path (one-shot slices have no lane clock to cross).
pub fn execute_static(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
) -> Result<ExecutionReport> {
    check_shapes(cluster, workload, alloc)?;
    let tau = workload.len();
    let (splits, offsets) = slice_layout(workload, alloc, None);

    struct LaneOut {
        latency: f64,
        sims: u64,
        errors: Vec<String>,
        stats: Vec<(usize, u64, PayoffStats)>, // (task, offset, slice stats)
    }
    let lane_outs: Vec<LaneOut> = parallel_map(
        (0..cluster.len()).collect(),
        cfg.workers.max(1),
        |i| {
            let platform = cluster.platform(i);
            let mut out = LaneOut { latency: 0.0, sims: 0, errors: Vec::new(), stats: Vec::new() };
            for (j, task) in workload.tasks.iter().enumerate() {
                let n = splits[j][i];
                if n == 0 || alloc.get(i, j) <= ALLOC_TOL {
                    continue;
                }
                let offset = offsets[j][i];
                let r = platform.execute(task, n, cfg.seed, ChunkCtx::cold(offset));
                out.latency += r.latency_secs;
                out.sims += n;
                match (r.stats, r.error) {
                    (Some(s), None) => out.stats.push((j, offset, s)),
                    (_, err) => out.errors.push(err.unwrap_or_else(|| "unknown".into())),
                }
            }
            out
        },
    );

    // Merge per-task statistics across platforms in offset order (the same
    // deterministic order the chunked path uses).
    let mut per_task: Vec<Vec<(u64, PayoffStats)>> = vec![Vec::new(); tau];
    let mut failures = 0usize;
    let mut chunks = 0usize;
    let specs = cluster.specs();
    let mut platforms = Vec::with_capacity(cluster.len());
    for (i, lane) in lane_outs.iter().enumerate() {
        for (j, offset, s) in &lane.stats {
            per_task[*j].push((*offset, *s));
            chunks += 1;
        }
        failures += lane.errors.len();
        let cm = specs[i].cost_model();
        platforms.push(PlatformReport {
            name: specs[i].name.clone(),
            latency_secs: lane.latency,
            quanta: cm.quanta(lane.latency),
            cost: cm.cost(lane.latency),
            sims: lane.sims,
            errors: lane.errors.clone(),
        });
    }
    let prices = per_task
        .iter_mut()
        .zip(&workload.tasks)
        .map(|(stats, t)| merge_chunk_stats(stats, t.discount()))
        .collect();
    Ok(ExecutionReport {
        makespan_secs: platforms.iter().map(|p| p.latency_secs).fold(0.0f64, f64::max),
        cost: platforms.iter().map(|p| p.cost).sum(),
        platforms,
        prices,
        failures,
        chunks,
        retries: 0,
        migrations: 0,
        preemptions: 0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::objectives::ModelSet;
    use crate::coordinator::partitioner::{HeuristicPartitioner, Partitioner};
    use crate::platforms::sim::SimConfig;
    use crate::platforms::spec::small_cluster;
    use crate::pricing::blackscholes;
    use crate::workload::option::Payoff;
    use crate::workload::{generate, GeneratorConfig};

    fn setup() -> (Cluster, Workload, ModelSet) {
        let specs = small_cluster();
        let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 21).unwrap();
        let workload = generate(&GeneratorConfig::small(5, 0.02, 13));
        let models = ModelSet::from_specs(&specs, &workload);
        (cluster, workload, models)
    }

    #[test]
    fn executes_single_platform_allocation() {
        let (cluster, workload, _) = setup();
        let alloc = Allocation::single_platform(3, 5, 0);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        assert_eq!(rep.failures, 0);
        assert!(rep.makespan_secs > 0.0);
        assert_eq!(rep.platforms[0].sims, workload.total_sims());
        assert_eq!(rep.platforms[1].sims, 0);
        assert_eq!(rep.platforms[1].cost, 0.0);
        assert!((rep.cost - rep.platforms[0].cost).abs() < 1e-12);
    }

    #[test]
    fn split_allocation_reduces_makespan() {
        let (cluster, workload, models) = setup();
        let solo = Allocation::single_platform(3, 5, 0);
        let split = HeuristicPartitioner::upper_bound_allocation(&models);
        let cfg = ExecutorConfig::default();
        let rs = execute(&cluster, &workload, &solo, &cfg).unwrap();
        let rp = execute(&cluster, &workload, &split, &cfg).unwrap();
        assert!(rp.makespan_secs < rs.makespan_secs);
    }

    #[test]
    fn makespan_is_max_platform_latency() {
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        let max_lane = rep
            .platforms
            .iter()
            .map(|p| p.latency_secs)
            .fold(0.0f64, f64::max);
        assert!((rep.makespan_secs - max_lane).abs() < 1e-9);
    }

    #[test]
    fn prices_remain_correct_under_partitioning() {
        // The end-to-end invariant: splitting a task across platforms must
        // not bias its price (counter-disjoint slices).
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        for (t, price) in workload.tasks.iter().zip(&rep.prices) {
            let est = price.as_ref().expect("price produced");
            if t.payoff == Payoff::European {
                let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
                assert!(
                    (est.price - bs).abs() < 6.0 * est.std_error + 0.1,
                    "task {}: {est:?} vs bs {bs}",
                    t.id
                );
            } else {
                assert!(est.price >= 0.0 && est.price < t.spot);
            }
        }
    }

    #[test]
    fn model_predictions_track_exact_execution() {
        // With a noise-free simulator and nominal==true models (exact sim
        // config has hidden_spread 0), predicted and realised agree closely.
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        let predicted = models.makespan(&alloc);
        let rel = (rep.makespan_secs - predicted).abs() / predicted;
        assert!(rel < 0.25, "predicted {predicted} vs measured {} ", rep.makespan_secs);
        let predicted_cost = models.total_cost(&alloc);
        assert!((rep.cost - predicted_cost).abs() / predicted_cost < 0.5);
    }

    #[test]
    fn chunked_equals_static_under_exact_sim() {
        // The refactor's core guarantee, at unit scale (the integration
        // test covers the full matrix): small chunks + retries + the
        // event loop reproduce the one-shot report.
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let base = ExecutorConfig::default();
        let chunked = ExecutorConfig {
            chunk_sims: 1 << 15,
            rebalance: RebalanceConfig { enabled: false, ..Default::default() },
            ..base.clone()
        };
        let rs = execute_static(&cluster, &workload, &alloc, &base).unwrap();
        let rc = execute(&cluster, &workload, &alloc, &chunked).unwrap();
        assert!(
            (rs.makespan_secs - rc.makespan_secs).abs() < 1e-9,
            "{} vs {}",
            rs.makespan_secs,
            rc.makespan_secs
        );
        assert!((rs.cost - rc.cost).abs() < 1e-9);
        for (a, b) in rs.prices.iter().zip(&rc.prices) {
            let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
            assert!((a.price - b.price).abs() < 1e-9);
            assert_eq!(a.n, b.n);
        }
        assert!(rc.chunks > rs.chunks, "chunking must actually split slices");
    }

    #[test]
    fn event_stream_reports_progress_and_prices() {
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let cfg = ExecutorConfig { chunk_sims: 1 << 16, ..Default::default() };
        let mut started = 0usize;
        let mut done = 0usize;
        let mut priced = Vec::new();
        let mut finished = false;
        let rep = execute_with(&cluster, &workload, &alloc, &cfg, Some(&models), &mut |ev| {
            match ev {
                ExecEvent::Started { chunks, .. } => started = *chunks,
                ExecEvent::ChunkDone { .. } => done += 1,
                ExecEvent::TaskPriced { task, .. } => priced.push(*task),
                ExecEvent::Finished { .. } => finished = true,
                _ => {}
            }
        })
        .unwrap();
        assert!(started > 0);
        assert_eq!(done, started);
        assert_eq!(done, rep.chunks);
        priced.sort();
        assert_eq!(priced, (0..workload.len()).collect::<Vec<_>>());
        assert!(finished);
    }

    #[test]
    fn failure_injection_without_retries_matches_legacy_reporting() {
        let specs = small_cluster();
        let cluster =
            Cluster::simulated(&specs, &SimConfig { failure_rate: 1.0, ..SimConfig::exact() }, 3)
                .unwrap();
        let workload = generate(&GeneratorConfig::small(3, 0.05, 1));
        let alloc = Allocation::single_platform(3, 3, 1);
        let cfg = ExecutorConfig {
            chunk_sims: 0, // one chunk per slice, like the legacy path
            retry: RetryConfig { max_attempts: 1, rehome: false },
            ..Default::default()
        };
        let rep = execute(&cluster, &workload, &alloc, &cfg).unwrap();
        assert_eq!(rep.failures, 3);
        assert_eq!(rep.retries, 0);
        assert!(rep.prices.iter().all(Option::is_none));
    }

    #[test]
    fn retries_rehome_around_a_failing_platform() {
        // One platform always fails; with re-homing retries every task
        // still gets its price.
        let specs = small_cluster();
        use crate::platforms::sim::SimPlatform;
        use crate::platforms::Platform;
        use std::sync::Arc;
        let mut platforms: Vec<Arc<dyn Platform>> = Vec::new();
        for (i, s) in specs.iter().enumerate() {
            let sim = if i == 0 {
                SimConfig { failure_rate: 1.0, ..SimConfig::exact() }
            } else {
                SimConfig::exact()
            };
            platforms.push(Arc::new(SimPlatform::new(s.clone(), sim, 21 + i as u64)));
        }
        let cluster = Cluster::new(platforms).unwrap();
        let workload = generate(&GeneratorConfig::small(4, 0.05, 9));
        let alloc = Allocation::proportional(3, 4, &[1.0, 1.0, 1.0]);
        let cfg = ExecutorConfig {
            chunk_sims: 1 << 16,
            retry: RetryConfig { max_attempts: 4, rehome: true },
            ..Default::default()
        };
        let rep = execute(&cluster, &workload, &alloc, &cfg).unwrap();
        assert!(rep.retries > 0, "the failing platform must trigger retries");
        assert_eq!(rep.failures, 0, "re-homed retries must land on healthy platforms");
        assert!(rep.prices.iter().all(Option::is_some));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (cluster, workload, _) = setup();
        let alloc = Allocation::single_platform(2, 5, 0); // wrong mu
        assert!(execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).is_err());
    }

    #[test]
    fn spot_preemption_rehomes_and_bills_quanta_actually_used() {
        // Platform 0 is a spot instance with an enormous preemption hazard:
        // it dies on its first chunk. With retries + re-homing every task
        // still prices, and the dead lane's bill stops at the preemption.
        let mut specs = small_cluster();
        specs[0].preemptible = Some(1e7); // preempts within milliseconds
        let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 21).unwrap();
        let workload = generate(&GeneratorConfig::small(4, 0.05, 9));
        let alloc = Allocation::proportional(3, 4, &[1.0, 1.0, 1.0]);
        let cfg = ExecutorConfig {
            chunk_sims: 1 << 16,
            retry: RetryConfig { max_attempts: 4, rehome: true },
            ..Default::default()
        };
        let mut preempt_events = 0usize;
        let rep = execute_with(&cluster, &workload, &alloc, &cfg, None, &mut |ev| {
            if let ExecEvent::LanePreempted { platform, at_secs, .. } = ev {
                assert_eq!(*platform, 0);
                assert!(*at_secs >= 0.0);
                preempt_events += 1;
            }
        })
        .unwrap();
        assert_eq!(rep.preemptions, 1, "the spot lane must die exactly once");
        assert_eq!(preempt_events, 1);
        assert_eq!(rep.failures, 0, "re-homed work must survive the preemption");
        assert!(rep.prices.iter().all(Option::is_some));
        // The bill covers only the quanta used before the preemption: the
        // lane time is capped at the drawn preemption point, which at this
        // hazard is far below one quantum of any small-cluster platform.
        let dead = &rep.platforms[0];
        assert!(dead.latency_secs < 10.0, "lane time not capped: {}", dead.latency_secs);
        assert!(dead.quanta <= 1, "billed past the preemption: {} quanta", dead.quanta);
        assert!(!dead.errors.is_empty());
    }

    #[test]
    fn all_lanes_preempted_fails_chunks_without_wedging() {
        // Every lane is a doomed spot instance: the run must terminate with
        // permanent failures (no prices), never deadlock.
        let mut specs = small_cluster();
        for s in &mut specs {
            s.preemptible = Some(1e7);
        }
        let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 5).unwrap();
        let workload = generate(&GeneratorConfig::small(2, 0.05, 3));
        let alloc = Allocation::proportional(3, 2, &[1.0, 1.0, 1.0]);
        let cfg = ExecutorConfig {
            chunk_sims: 1 << 16,
            retry: RetryConfig { max_attempts: 3, rehome: true },
            ..Default::default()
        };
        let rep = execute(&cluster, &workload, &alloc, &cfg).unwrap();
        assert_eq!(rep.preemptions, 3);
        assert!(rep.failures > 0);
        assert!(rep.prices.iter().all(Option::is_none));
    }

    #[test]
    fn epoch_with_loose_boundary_matches_full_run() {
        // A boundary beyond the whole run is a no-op: nothing deferred,
        // identical report to the plain chunked path.
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let cfg = ExecutorConfig { chunk_sims: 1 << 16, ..Default::default() };
        let full = execute(&cluster, &workload, &alloc, &cfg).unwrap();
        let bases = vec![0u64; workload.len()];
        let ep = execute_epoch(
            &cluster,
            &workload,
            &alloc,
            &cfg,
            Some(&models),
            EpochCtx { halt_secs: 1e12, base_offsets: &bases },
            &mut |_| {},
        )
        .unwrap();
        assert!((ep.exec.makespan_secs - full.makespan_secs).abs() < 1e-9);
        assert!(ep.deferred_sims.iter().all(|&d| d == 0));
        for (j, t) in workload.tasks.iter().enumerate() {
            assert_eq!(ep.done_sims[j], t.n_sims);
            assert!(ep.stats[j].n > 0);
            let (a, b) = (
                ep.exec.prices[j].as_ref().unwrap(),
                full.prices[j].as_ref().unwrap(),
            );
            assert!((a.price - b.price).abs() < 1e-9);
        }
    }

    #[test]
    fn epoch_boundary_defers_work_and_epochs_compose() {
        // A tight boundary leaves work queued (deferred, not failed); a
        // second epoch over the remainder at shifted counter bases finishes
        // the job, and the merged statistics cover every requested path.
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let cfg = ExecutorConfig { chunk_sims: 1 << 14, ..Default::default() };
        let bases = vec![0u64; workload.len()];
        // The boundary sits well inside the run: the full makespan of this
        // allocation is far larger than one chunk's latency.
        let full = execute(&cluster, &workload, &alloc, &cfg).unwrap();
        let halt = full.makespan_secs / 4.0;
        let ep1 = execute_epoch(
            &cluster,
            &workload,
            &alloc,
            &cfg,
            Some(&models),
            EpochCtx { halt_secs: halt, base_offsets: &bases },
            &mut |_| {},
        )
        .unwrap();
        assert_eq!(ep1.exec.failures, 0);
        let total_deferred: u64 = ep1.deferred_sims.iter().sum();
        assert!(total_deferred > 0, "tight boundary must strand work");
        for (j, t) in workload.tasks.iter().enumerate() {
            assert_eq!(ep1.done_sims[j] + ep1.deferred_sims[j], t.n_sims);
        }
        // Dispatch stopped at the boundary: the epoch is strictly shorter
        // than the uninterrupted run.
        assert!(ep1.exec.makespan_secs < full.makespan_secs);
        // Epoch 2: remaining work at fresh counter bases.
        let mut rest = workload.clone();
        let bases2: Vec<u64> = workload.tasks.iter().map(|t| t.n_sims).collect();
        for (j, t) in rest.tasks.iter_mut().enumerate() {
            t.n_sims = (t.n_sims - ep1.done_sims[j]).max(1);
        }
        let ep2 = execute_epoch(
            &cluster,
            &rest,
            &alloc,
            &cfg,
            Some(&models),
            EpochCtx { halt_secs: 1e12, base_offsets: &bases2 },
            &mut |_| {},
        )
        .unwrap();
        assert!(ep2.deferred_sims.iter().all(|&d| d == 0));
        for j in 0..workload.len() {
            // The sim caps *statistics* per stream, so compare structure,
            // not raw path counts: merging epochs accumulates stats.
            let merged = ep1.stats[j].merge(&ep2.stats[j]);
            assert!(merged.n >= ep1.stats[j].n.max(ep2.stats[j].n));
            assert!(merged.n > 0);
            assert_eq!(ep2.done_sims[j], rest.tasks[j].n_sims);
        }
        // Degenerate epochs are rejected.
        assert!(execute_epoch(
            &cluster,
            &workload,
            &alloc,
            &cfg,
            None,
            EpochCtx { halt_secs: 0.0, base_offsets: &bases },
            &mut |_| {},
        )
        .is_err());
        assert!(execute_epoch(
            &cluster,
            &workload,
            &alloc,
            &cfg,
            None,
            EpochCtx { halt_secs: 1.0, base_offsets: &bases[..2] },
            &mut |_| {},
        )
        .is_err());
    }

    #[test]
    fn on_demand_runs_are_untouched_by_the_spot_machinery() {
        // No preemptible spec -> bit-identical reports with the scenario
        // code compiled in (the chunked/static equivalence depends on it).
        let (cluster, workload, _) = setup();
        let alloc = Allocation::single_platform(3, 5, 1);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        assert_eq!(rep.preemptions, 0);
        assert_eq!(rep.failures, 0);
    }
}
