//! Execute an allocation on a cluster and measure what *actually* happens —
//! the "we then ran the resulting partitions on our experimental hardware"
//! step that produces the measured curves of Fig. 3.
//!
//! Each platform gets one worker thread and a private [`SimLane`] timeline:
//! it processes its assigned task slices sequentially (latency accumulates
//! on the lane), simulated platforms advancing virtual time and the native
//! platform real time. The realised makespan is the max lane time; realised
//! cost quantises each lane's total through the platform's billing terms.

use std::sync::Arc;

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::allocation::{Allocation, ALLOC_TOL};
use crate::platforms::Cluster;
use crate::pricing::mc::{combine, PayoffStats, PriceEstimate};
use crate::util::sim_time::SimClock;
use crate::util::threadpool::parallel_map;
use crate::workload::Workload;

/// Per-platform execution record.
#[derive(Debug, Clone)]
pub struct PlatformReport {
    pub name: String,
    /// Total busy time on this platform's lane, seconds.
    pub latency_secs: f64,
    /// Billed quanta and cost.
    pub quanta: u64,
    pub cost: f64,
    /// Simulations actually dispatched here.
    pub sims: u64,
    pub errors: Vec<String>,
}

/// Whole-run execution record.
#[derive(Debug)]
pub struct ExecutionReport {
    /// Realised makespan (max platform latency), seconds.
    pub makespan_secs: f64,
    /// Realised total billed cost, $.
    pub cost: f64,
    pub platforms: Vec<PlatformReport>,
    /// Discounted price estimate per task (None if every slice failed).
    pub prices: Vec<Option<PriceEstimate>>,
    /// Total failed slices.
    pub failures: usize,
}

/// Execution controls.
#[derive(Debug, Clone)]
pub struct ExecutorConfig {
    pub seed: u32,
    /// Worker threads (>= cluster size recommended; each platform runs its
    /// queue sequentially regardless).
    pub threads: usize,
}

impl Default for ExecutorConfig {
    fn default() -> Self {
        ExecutorConfig { seed: 1, threads: 16 }
    }
}

/// Run `alloc` for `workload` on `cluster`.
pub fn execute(
    cluster: &Cluster,
    workload: &Workload,
    alloc: &Allocation,
    cfg: &ExecutorConfig,
) -> Result<ExecutionReport> {
    alloc.validate()?;
    workload.validate()?;
    if alloc.n_platforms() != cluster.len() || alloc.n_tasks() != workload.len() {
        return Err(CloudshapesError::runtime(format!(
            "allocation shape {}x{} vs cluster {} / workload {}",
            alloc.n_platforms(),
            alloc.n_tasks(),
            cluster.len(),
            workload.len()
        )));
    }
    let tau = workload.len();

    // Integer-split every task's path space and compute per-slice counter
    // offsets (prefix sums keep slices disjoint).
    let splits: Vec<Vec<u64>> = (0..tau)
        .map(|j| alloc.split_sims(j, workload.tasks[j].n_sims))
        .collect();
    let offsets: Vec<Vec<u64>> = splits
        .iter()
        .map(|row| {
            let mut acc = 0u64;
            row.iter()
                .map(|n| {
                    let o = acc;
                    acc += n;
                    o
                })
                .collect()
        })
        .collect();

    let clock = SimClock::new();
    struct LaneOut {
        latency: f64,
        sims: u64,
        errors: Vec<String>,
        stats: Vec<(usize, PayoffStats)>, // (task, slice stats)
    }
    let lane_outs: Vec<LaneOut> = parallel_map(
        (0..cluster.len()).collect(),
        cfg.threads.max(1),
        |i| {
            let platform: &Arc<_> = cluster.platform(i);
            let mut lane = clock.lane();
            let mut out =
                LaneOut { latency: 0.0, sims: 0, errors: Vec::new(), stats: Vec::new() };
            for (j, task) in workload.tasks.iter().enumerate() {
                let n = splits[j][i];
                if n == 0 || alloc.get(i, j) <= ALLOC_TOL {
                    continue;
                }
                let offset = (offsets[j][i] % u32::MAX as u64) as u32;
                let r = platform.execute(task, n, cfg.seed, offset);
                lane.advance(r.latency_secs);
                out.sims += n;
                match (r.stats, r.error) {
                    (Some(s), None) => out.stats.push((j, s)),
                    (_, err) => out.errors.push(err.unwrap_or_else(|| "unknown".into())),
                }
            }
            out.latency = lane.now_secs();
            out
        },
    );

    // Merge per-task statistics across platforms.
    let mut merged: Vec<PayoffStats> = vec![PayoffStats::default(); tau];
    let mut failures = 0usize;
    let specs = cluster.specs();
    let mut platforms = Vec::with_capacity(cluster.len());
    for (i, lane) in lane_outs.iter().enumerate() {
        for (j, s) in &lane.stats {
            merged[*j] = merged[*j].merge(s);
        }
        failures += lane.errors.len();
        let cm = specs[i].cost_model();
        platforms.push(PlatformReport {
            name: specs[i].name.clone(),
            latency_secs: lane.latency,
            quanta: cm.quanta(lane.latency),
            cost: cm.cost(lane.latency),
            sims: lane.sims,
            errors: lane.errors.clone(),
        });
    }
    let prices = merged
        .iter()
        .zip(&workload.tasks)
        .map(|(s, t)| if s.n > 0 { Some(combine(s, t.discount())) } else { None })
        .collect();
    Ok(ExecutionReport {
        makespan_secs: clock.high_water_secs(),
        cost: platforms.iter().map(|p| p.cost).sum(),
        platforms,
        prices,
        failures,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::objectives::ModelSet;
    use crate::coordinator::partitioner::{HeuristicPartitioner, Partitioner};
    use crate::platforms::sim::SimConfig;
    use crate::platforms::spec::small_cluster;
    use crate::pricing::blackscholes;
    use crate::workload::option::Payoff;
    use crate::workload::{generate, GeneratorConfig};

    fn setup() -> (Cluster, Workload, ModelSet) {
        let specs = small_cluster();
        let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 21);
        let workload = generate(&GeneratorConfig::small(5, 0.02, 13));
        let models = ModelSet::from_specs(&specs, &workload);
        (cluster, workload, models)
    }

    #[test]
    fn executes_single_platform_allocation() {
        let (cluster, workload, _) = setup();
        let alloc = Allocation::single_platform(3, 5, 0);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        assert_eq!(rep.failures, 0);
        assert!(rep.makespan_secs > 0.0);
        assert_eq!(rep.platforms[0].sims, workload.total_sims());
        assert_eq!(rep.platforms[1].sims, 0);
        assert_eq!(rep.platforms[1].cost, 0.0);
        assert!((rep.cost - rep.platforms[0].cost).abs() < 1e-12);
    }

    #[test]
    fn split_allocation_reduces_makespan() {
        let (cluster, workload, models) = setup();
        let solo = Allocation::single_platform(3, 5, 0);
        let split = HeuristicPartitioner::upper_bound_allocation(&models);
        let cfg = ExecutorConfig::default();
        let rs = execute(&cluster, &workload, &solo, &cfg).unwrap();
        let rp = execute(&cluster, &workload, &split, &cfg).unwrap();
        assert!(rp.makespan_secs < rs.makespan_secs);
    }

    #[test]
    fn makespan_is_max_platform_latency() {
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        let max_lane = rep
            .platforms
            .iter()
            .map(|p| p.latency_secs)
            .fold(0.0f64, f64::max);
        assert!((rep.makespan_secs - max_lane).abs() < 1e-9);
    }

    #[test]
    fn prices_remain_correct_under_partitioning() {
        // The end-to-end invariant: splitting a task across platforms must
        // not bias its price (counter-disjoint slices).
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        for (t, price) in workload.tasks.iter().zip(&rep.prices) {
            let est = price.as_ref().expect("price produced");
            if t.payoff == Payoff::European {
                let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
                assert!(
                    (est.price - bs).abs() < 6.0 * est.std_error + 0.1,
                    "task {}: {est:?} vs bs {bs}",
                    t.id
                );
            } else {
                assert!(est.price >= 0.0 && est.price < t.spot);
            }
        }
    }

    #[test]
    fn model_predictions_track_exact_execution() {
        // With a noise-free simulator and nominal==true models (exact sim
        // config has hidden_spread 0), predicted and realised agree closely.
        let (cluster, workload, models) = setup();
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        let predicted = models.makespan(&alloc);
        let rel = (rep.makespan_secs - predicted).abs() / predicted;
        assert!(rel < 0.25, "predicted {predicted} vs measured {} ", rep.makespan_secs);
        let predicted_cost = models.total_cost(&alloc);
        assert!((rep.cost - predicted_cost).abs() / predicted_cost < 0.5);
    }

    #[test]
    fn failure_injection_is_reported() {
        let specs = small_cluster();
        let cluster =
            Cluster::simulated(&specs, &SimConfig { failure_rate: 1.0, ..SimConfig::exact() }, 3);
        let workload = generate(&GeneratorConfig::small(3, 0.05, 1));
        let alloc = Allocation::single_platform(3, 3, 1);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        assert_eq!(rep.failures, 3);
        assert!(rep.prices.iter().all(Option::is_none));
    }

    #[test]
    fn shape_mismatch_rejected() {
        let (cluster, workload, _) = setup();
        let alloc = Allocation::single_platform(2, 5, 0); // wrong mu
        assert!(execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).is_err());
    }
}
