//! Braun et al. static mapping heuristics — the literature baselines the
//! paper positions against (§II.B, [5]). All assign *whole* tasks (binary
//! allocations), optimise makespan only, and ignore billing: they exist for
//! the ablation benches comparing divisible-MILP against classic whole-task
//! mapping.
//!
//! Implemented: OLB, MET, MCT, Min-Min, Max-Min, Sufferage.

use crate::api::error::Result;
use crate::coordinator::allocation::Allocation;
use crate::coordinator::objectives::ModelSet;

use super::Partitioner;

/// Which classic heuristic to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Classic {
    /// Opportunistic Load Balancing: next task to the earliest-ready
    /// platform, ignoring execution time.
    Olb,
    /// Minimum Execution Time: each task to its fastest platform,
    /// ignoring load.
    Met,
    /// Minimum Completion Time: each task (arrival order) to the platform
    /// finishing it earliest.
    Mct,
    /// Min-Min: repeatedly commit the task with the smallest best
    /// completion time.
    MinMin,
    /// Max-Min: repeatedly commit the task with the *largest* best
    /// completion time.
    MaxMin,
    /// Sufferage: commit the task that would suffer most if denied its best
    /// platform.
    Sufferage,
}

impl Classic {
    pub fn all() -> [Classic; 6] {
        [
            Classic::Olb,
            Classic::Met,
            Classic::Mct,
            Classic::MinMin,
            Classic::MaxMin,
            Classic::Sufferage,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Classic::Olb => "olb",
            Classic::Met => "met",
            Classic::Mct => "mct",
            Classic::MinMin => "min-min",
            Classic::MaxMin => "max-min",
            Classic::Sufferage => "sufferage",
        }
    }
}

/// Whole-task mapping heuristic baseline.
#[derive(Debug, Clone, Copy)]
pub struct ClassicPartitioner(pub Classic);

impl ClassicPartitioner {
    /// Execution time of whole task `j` on platform `i` (work + setup).
    fn etc(models: &ModelSet, i: usize, j: usize) -> f64 {
        models.work_secs(i, j) + models.setup_secs(i, j)
    }

    fn assign(models: &ModelSet, kind: Classic) -> Vec<usize> {
        let (mu, tau) = (models.mu, models.tau);
        let mut ready = vec![0.0f64; mu]; // per-platform ready time
        let mut assignment = vec![usize::MAX; tau];

        match kind {
            Classic::Olb | Classic::Met | Classic::Mct => {
                for j in 0..tau {
                    let i = match kind {
                        Classic::Olb => argmin(&(0..mu).map(|i| ready[i]).collect::<Vec<_>>()),
                        Classic::Met => argmin(
                            &(0..mu).map(|i| Self::etc(models, i, j)).collect::<Vec<_>>(),
                        ),
                        Classic::Mct => argmin(
                            &(0..mu)
                                .map(|i| ready[i] + Self::etc(models, i, j))
                                .collect::<Vec<_>>(),
                        ),
                        _ => unreachable!(),
                    };
                    assignment[j] = i;
                    ready[i] += Self::etc(models, i, j);
                }
            }
            Classic::MinMin | Classic::MaxMin | Classic::Sufferage => {
                let mut unassigned: Vec<usize> = (0..tau).collect();
                while !unassigned.is_empty() {
                    // For each unassigned task: best and second-best
                    // completion times.
                    let mut pick = 0usize; // index into unassigned
                    let mut pick_platform = 0usize;
                    let mut pick_key = f64::NEG_INFINITY;
                    for (u, &j) in unassigned.iter().enumerate() {
                        let cts: Vec<f64> = (0..mu)
                            .map(|i| ready[i] + Self::etc(models, i, j))
                            .collect();
                        let best_i = argmin(&cts);
                        let best = cts[best_i];
                        let second = cts
                            .iter()
                            .enumerate()
                            .filter(|(i, _)| *i != best_i)
                            .map(|(_, c)| *c)
                            .fold(f64::INFINITY, f64::min);
                        let key = match kind {
                            Classic::MinMin => -best,          // smallest best CT
                            Classic::MaxMin => best,           // largest best CT
                            Classic::Sufferage => second - best, // max sufferage
                            _ => unreachable!(),
                        };
                        if key > pick_key {
                            pick_key = key;
                            pick = u;
                            pick_platform = best_i;
                        }
                    }
                    let j = unassigned.swap_remove(pick);
                    assignment[j] = pick_platform;
                    ready[pick_platform] += Self::etc(models, pick_platform, j);
                }
            }
        }
        assignment
    }
}

fn argmin(xs: &[f64]) -> usize {
    xs.iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .expect("non-empty")
}

impl Partitioner for ClassicPartitioner {
    fn name(&self) -> &str {
        self.0.name()
    }

    /// Budget is ignored: the classic heuristics are makespan-only mappers.
    fn partition(&self, models: &ModelSet, _budget: Option<f64>) -> Result<Allocation> {
        let assignment = Self::assign(models, self.0);
        let mut alloc = Allocation::zero(models.mu, models.tau);
        for (j, i) in assignment.iter().enumerate() {
            alloc.set(*i, j, 1.0);
        }
        alloc.validate()?;
        Ok(alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{CostModel, LatencyModel};

    fn models() -> ModelSet {
        // 3 platforms with distinct speeds, 6 tasks of mixed sizes.
        let betas = [1e-4, 5e-4, 2e-3];
        let n: Vec<u64> = vec![1_000_000, 500_000, 2_000_000, 100_000, 800_000, 1_500_000];
        let mut latency = Vec::new();
        for b in betas {
            for _ in 0..n.len() {
                latency.push(LatencyModel::new(b, 1.0));
            }
        }
        ModelSet::new(
            latency,
            vec![
                CostModel::new(3600.0, 1.0).unwrap(),
                CostModel::new(3600.0, 0.5).unwrap(),
                CostModel::new(60.0, 0.3).unwrap(),
            ],
            n,
            vec!["a".into(), "b".into(), "c".into()],
        )
    }

    #[test]
    fn all_heuristics_produce_valid_binary_allocations() {
        let m = models();
        for kind in Classic::all() {
            let alloc = ClassicPartitioner(kind).partition(&m, None).unwrap();
            assert!(alloc.validate().is_ok(), "{kind:?}");
            for i in 0..m.mu {
                for j in 0..m.tau {
                    let a = alloc.get(i, j);
                    assert!(a == 0.0 || a == 1.0, "{kind:?} fractional entry");
                }
            }
        }
    }

    #[test]
    fn met_puts_everything_on_fastest() {
        let m = models();
        let alloc = ClassicPartitioner(Classic::Met).partition(&m, None).unwrap();
        assert_eq!(alloc.used_platforms(), vec![0]); // platform 0 has min beta
    }

    #[test]
    fn mct_balances_better_than_met() {
        let m = models();
        let met = ClassicPartitioner(Classic::Met).partition(&m, None).unwrap();
        let mct = ClassicPartitioner(Classic::Mct).partition(&m, None).unwrap();
        assert!(m.makespan(&mct) <= m.makespan(&met) + 1e-9);
        assert!(mct.used_platforms().len() > 1);
    }

    #[test]
    fn minmin_no_worse_than_olb() {
        // Braun's empirical finding (Min-Min among the best, OLB worst).
        let m = models();
        let olb = ClassicPartitioner(Classic::Olb).partition(&m, None).unwrap();
        let minmin = ClassicPartitioner(Classic::MinMin).partition(&m, None).unwrap();
        assert!(m.makespan(&minmin) <= m.makespan(&olb) + 1e-9);
    }

    #[test]
    fn sufferage_valid_and_complete() {
        let m = models();
        let s = ClassicPartitioner(Classic::Sufferage).partition(&m, None).unwrap();
        assert!(s.validate().is_ok());
        // Every task assigned exactly once.
        for j in 0..m.tau {
            assert!((s.column_sum(j) - 1.0).abs() < 1e-12);
        }
    }
}
