//! The paper's "common-sense" heuristic partitioner (§III.C).
//!
//! * **Upper cost bound C_U** — divide work inversely proportional to each
//!   platform's *individual makespan* (its latency running the entire
//!   workload alone).
//! * **Lower cost bound C_L** — all tasks on the single cheapest platform.
//! * **Interior points** — platform weights from a linear combination of the
//!   *normalised* latency and cost: as the cost weighting λ grows, the
//!   allocation slides from the C_U split towards the cheapest platform.
//!
//! Deliberately ignores the γ setup non-linearity and the billing-quantum
//! ceiling — "only considering absolute latency and cost" — which is exactly
//! why the MILP beats it at interior budgets (Table IV) and why it never
//! touches the short-quantum CPUs (§IV.C.2).

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::allocation::Allocation;
use crate::coordinator::objectives::ModelSet;

use super::{lower_cost_bound, Partitioner};

/// Paper heuristic. `lambda_grid` controls how finely the interior λ sweep
/// searches for a budget-respecting allocation.
#[derive(Debug, Clone)]
pub struct HeuristicPartitioner {
    pub lambda_grid: usize,
}

impl Default for HeuristicPartitioner {
    fn default() -> Self {
        HeuristicPartitioner { lambda_grid: 101 }
    }
}

impl HeuristicPartitioner {
    /// The C_U allocation: inverse-individual-makespan proportional split.
    pub fn upper_bound_allocation(models: &ModelSet) -> Allocation {
        let weights: Vec<f64> =
            (0..models.mu).map(|i| 1.0 / models.solo_latency(i).max(1e-12)).collect();
        Allocation::proportional(models.mu, models.tau, &weights)
    }

    /// The allocation at cost-weighting λ ∈ [0, 1].
    ///
    /// Platforms are scored by the normalised latency-cost linear
    /// combination `(1-λ)·L̃ᵢ + λ·C̃ᵢ`; platforms whose score-weight falls
    /// below λ·max-weight are dropped, and the survivors share work in
    /// inverse proportion to their individual makespans. λ = 0 keeps every
    /// platform (the C_U split); λ = 1 keeps only the cheapest (C_L).
    pub fn allocation_at_lambda(models: &ModelSet, lambda: f64) -> Allocation {
        if lambda >= 1.0 {
            return lower_cost_bound(models).1;
        }
        // Normalised (relative) solo latency and cost per platform.
        let lat: Vec<f64> = (0..models.mu).map(|i| models.solo_latency(i)).collect();
        let cost: Vec<f64> = (0..models.mu).map(|i| models.solo_cost(i)).collect();
        let lmin = lat.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-12);
        let cmin = cost.iter().cloned().fold(f64::INFINITY, f64::min).max(1e-12);
        let scores: Vec<f64> = (0..models.mu)
            .map(|i| (1.0 - lambda) * lat[i] / lmin + lambda * cost[i] / cmin)
            .collect();
        // Keep the top-k platforms by score, k sliding from μ (λ=0) to 1
        // (λ→1); the worst-scoring platforms — the short-quantum CPUs, whose
        // solo latency is enormous — drop out first, reproducing §IV.C.2's
        // "the heuristic approach does not consider [the CPUs] at all".
        let keep = ((models.mu as f64 * (1.0 - lambda)).round() as usize).clamp(1, models.mu);
        let mut order: Vec<usize> = (0..models.mu).collect();
        order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        let mut weights = vec![0.0; models.mu];
        for &i in order.iter().take(keep) {
            weights[i] = 1.0 / lat[i].max(1e-12); // inverse-makespan among kept
        }
        Allocation::proportional(models.mu, models.tau, &weights)
    }
}

impl Partitioner for HeuristicPartitioner {
    fn name(&self) -> &str {
        "heuristic"
    }

    fn partition(&self, models: &ModelSet, budget: Option<f64>) -> Result<Allocation> {
        let Some(budget) = budget else {
            return Ok(Self::upper_bound_allocation(models));
        };
        // Sweep λ from the fast end; keep the fastest allocation within
        // budget. λ = 1 (single cheapest platform) is the fallback.
        let mut best: Option<(f64, Allocation)> = None;
        for k in 0..self.lambda_grid {
            let lambda = k as f64 / (self.lambda_grid - 1).max(1) as f64;
            let alloc = Self::allocation_at_lambda(models, lambda);
            let (latency, cost) = models.evaluate(&alloc);
            if cost <= budget + 1e-9
                && best.as_ref().map(|(l, _)| latency < *l).unwrap_or(true)
            {
                best = Some((latency, alloc));
            }
        }
        let fallback = lower_cost_bound(models);
        match best {
            Some((_, alloc)) => Ok(alloc),
            None if fallback.0 <= budget + 1e-9 => Ok(fallback.1),
            None => Err(CloudshapesError::solver(format!(
                "heuristic: budget ${budget:.3} below the cheapest single-platform cost ${:.3}",
                fallback.0
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{CostModel, LatencyModel};

    fn models() -> ModelSet {
        let l = |b, g| LatencyModel::new(b, g);
        // Three platforms: fast+expensive, medium, slow+cheap.
        ModelSet::new(
            vec![
                l(1e-4, 5.0),
                l(1e-4, 5.0),
                l(1e-3, 2.0),
                l(1e-3, 2.0),
                l(1e-2, 0.5),
                l(1e-2, 0.5),
            ],
            vec![
                CostModel::new(3600.0, 2.0).unwrap(),
                CostModel::new(3600.0, 0.6).unwrap(),
                CostModel::new(60.0, 0.3).unwrap(),
            ],
            vec![1_000_000, 2_000_000],
            vec!["fast".into(), "mid".into(), "cheap".into()],
        )
    }

    #[test]
    fn unconstrained_gives_inverse_makespan_split() {
        let m = models();
        let a = HeuristicPartitioner::default().partition(&m, None).unwrap();
        assert!(a.validate().is_ok());
        // Weights prop. to 1/solo_latency: platform 0 fastest -> biggest share.
        assert!(a.get(0, 0) > a.get(1, 0));
        assert!(a.get(1, 0) > a.get(2, 0));
        // All tasks get the same split (the heuristic is task-blind).
        assert!((a.get(0, 0) - a.get(0, 1)).abs() < 1e-12);
    }

    #[test]
    fn lambda_one_is_single_cheapest() {
        let m = models();
        let a = HeuristicPartitioner::allocation_at_lambda(&m, 1.0);
        assert_eq!(a.used_platforms().len(), 1);
    }

    #[test]
    fn budget_tightening_reduces_cost_monotonely() {
        let m = models();
        let h = HeuristicPartitioner::default();
        let unconstrained = HeuristicPartitioner::upper_bound_allocation(&m);
        let cu = m.total_cost(&unconstrained);
        let (cl, _) = crate::coordinator::partitioner::lower_cost_bound(&m);
        let mut last_latency = 0.0;
        for frac in [1.0, 0.75, 0.5, 0.25, 0.0] {
            let budget = cl + frac * (cu - cl);
            let a = h.partition(&m, Some(budget)).unwrap();
            let (lat, cost) = m.evaluate(&a);
            assert!(cost <= budget + 1e-9, "cost {cost} > budget {budget}");
            assert!(lat >= last_latency - 1e-9, "latency not monotone");
            last_latency = lat;
        }
    }

    #[test]
    fn impossible_budget_errors() {
        let m = models();
        let h = HeuristicPartitioner::default();
        assert!(h.partition(&m, Some(1e-6)).is_err());
    }

    #[test]
    fn heuristic_is_task_blind_by_design() {
        // The allocation share of a platform must be identical across tasks
        // (the heuristic considers only aggregate platform characteristics).
        let m = models();
        let a = HeuristicPartitioner::allocation_at_lambda(&m, 0.4);
        for i in 0..m.mu {
            for j in 1..m.tau {
                assert!((a.get(i, j) - a.get(i, 0)).abs() < 1e-12);
            }
        }
    }
}
