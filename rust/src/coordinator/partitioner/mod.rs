//! Partitioners: strategies that turn a [`ModelSet`] (+ optional budget)
//! into an [`Allocation`].

pub mod baselines;
pub mod heuristic;
pub mod milp;

pub use heuristic::HeuristicPartitioner;
pub use milp::{MilpConfig, MilpPartitioner};

use crate::api::error::Result;

use super::allocation::Allocation;
use super::objectives::ModelSet;

/// A workload partitioning strategy (§III.C). `Send` so a boxed strategy
/// can move onto a background solver thread (the online scheduler re-solves
/// on its epoch thread); strategies are plain data, so this costs nothing.
pub trait Partitioner: Send {
    fn name(&self) -> &str;

    /// Produce an allocation. `budget` is the cost constraint C_k in $;
    /// `None` means unconstrained (the latency-optimal end of the curve).
    fn partition(&self, models: &ModelSet, budget: Option<f64>) -> Result<Allocation>;
}

/// Shared helper: the single platform that completes the whole workload at
/// the lowest billed cost (the C_L lower bound both approaches share).
pub fn cheapest_single_platform(models: &ModelSet) -> usize {
    (0..models.mu)
        .min_by(|&a, &b| {
            let (ca, cb) = (models.solo_cost(a), models.solo_cost(b));
            // NaN-safe total order (degenerate model fits must not panic);
            // tie-break on latency so the choice is deterministic.
            ca.total_cmp(&cb)
                .then(models.solo_latency(a).total_cmp(&models.solo_latency(b)))
        })
        .expect("non-empty model set")
}

/// The lower cost bound C_L and its allocation (step 2 of §III.C).
pub fn lower_cost_bound(models: &ModelSet) -> (f64, Allocation) {
    let i = cheapest_single_platform(models);
    let alloc = Allocation::single_platform(models.mu, models.tau, i);
    (models.total_cost(&alloc), alloc)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{CostModel, LatencyModel};

    fn models() -> ModelSet {
        let l = |b, g| LatencyModel::new(b, g);
        ModelSet::new(
            vec![l(1e-3, 10.0), l(1e-3, 10.0), l(4e-3, 1.0), l(4e-3, 1.0)],
            vec![CostModel::new(3600.0, 0.65).unwrap(), CostModel::new(60.0, 0.48).unwrap()],
            vec![100_000, 200_000],
            vec!["fast".into(), "cheapish".into()],
        )
    }

    #[test]
    fn cheapest_platform_is_found() {
        let m = models();
        // p0 solo: 320 s -> $0.65. p1 solo: 1202 s -> 21 quanta -> $0.168.
        assert_eq!(cheapest_single_platform(&m), 1);
        let (cl, alloc) = lower_cost_bound(&m);
        assert!((cl - 0.168).abs() < 1e-9);
        assert!(alloc.validate().is_ok());
        assert_eq!(alloc.used_platforms(), vec![1]);
    }
}
