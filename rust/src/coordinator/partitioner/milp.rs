//! The Mixed-ILP partitioner — Equation 4 of the paper, solved with a
//! structure-aware branch & bound over the in-tree simplex.
//!
//! # Formulation (Eq. 4)
//!
//! ```text
//! minimise F_L
//! s.t.  Σᵢ Aᵢⱼ = 1                          ∀j
//!       Σⱼ (βᵢⱼNⱼ Aᵢⱼ + γᵢⱼ Bᵢⱼ) ≤ F_L       ∀i      (platform latency)
//!       Aᵢⱼ ≤ Bᵢⱼ,  Bᵢⱼ ∈ {0,1}                      (γ ceiling indicator)
//!       G_L,ᵢ / ρᵢ ≤ Dᵢ,  Dᵢ ∈ ℤ₊                     (billing quanta)
//!       Σᵢ πᵢ Dᵢ ≤ C_k                                (budget)
//! ```
//!
//! # Structure-aware reduction
//!
//! In the LP relaxation the optimal B is exactly A (B appears only in the
//! latency rows with coefficient γ ≥ 0 and in A ≤ B ≤ 1), so instead of
//! carrying μ·τ B columns and μ·τ linking rows, the node LP charges γ·A for
//! *undecided* entries — an under-charge of γ(⌈A⌉−A) ≥ 0, hence still a
//! valid lower bound. Branching restores exactness:
//!
//! * `Off`  (B=0): A fixed to 0;
//! * `On`   (B=1): γ charged as a constant, A free in [0,1];
//! * `Free`: γ·A in the LP.
//!
//! D stays continuous in node LPs (again a valid lower bound on the
//! quantised cost); D-branching (`D ≤ ⌊d⌋` / `D ≥ ⌈d⌉`) closes the quantum
//! gap when it is the blocker. Incumbents are always evaluated with the TRUE
//! ceiling semantics of [`ModelSet`], so any returned allocation is honestly
//! feasible; the reported `gap` bounds its sub-optimality.
//!
//! This reduction is validated against the generic `milp::branch_bound`
//! solver (full Eq. 4 with explicit B) on small instances in
//! `rust/tests/milp_equivalence.rs`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::api::error::{CloudshapesError, Result};
use crate::coordinator::allocation::{Allocation, ALLOC_TOL};
use crate::coordinator::objectives::ModelSet;
use crate::milp::lp::{Cmp, Problem};
use crate::milp::simplex::{self, LpStatus};
use crate::util::threadpool::parallel_map;

use super::heuristic::HeuristicPartitioner;
use super::{lower_cost_bound, Partitioner};

/// Search budgets. The defaults solve the 128×16 paper instance to sub-%
/// gaps in seconds (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone)]
pub struct MilpConfig {
    pub max_nodes: usize,
    pub rel_gap: f64,
    pub time_limit_secs: f64,
    /// Threads solving node LPs. Each best-first round pops up to `workers`
    /// frontier nodes and solves their LPs concurrently; all search
    /// bookkeeping (incumbents, pruning, branching) stays sequential in
    /// node order, so results do not depend on thread scheduling.
    pub workers: usize,
}

impl Default for MilpConfig {
    fn default() -> Self {
        // At paper scale virtually all incumbent quality arrives from the
        // seed ladder + the root LP (measured: identical makespan at 1, 50
        // and 200 node budgets — EXPERIMENTS.md §Perf); the residual gap
        // reflects the weak B = A root bound, not a findable better
        // allocation. Budgets sized accordingly.
        MilpConfig { max_nodes: 60, rel_gap: 5e-3, time_limit_secs: 5.0, workers: 1 }
    }
}

/// Detailed solve outcome (the [`Partitioner`] impl returns just the
/// allocation; benches want the rest).
#[derive(Debug, Clone)]
pub struct MilpOutcome {
    pub alloc: Allocation,
    /// True (ceiling-semantics) makespan of `alloc`.
    pub makespan: f64,
    /// True billed cost of `alloc`.
    pub cost: f64,
    /// Proven lower bound on the optimal makespan.
    pub bound: f64,
    /// Relative optimality gap of `alloc`.
    pub gap: f64,
    pub nodes: usize,
}

/// Entry decision state in the B&B tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Entry {
    Free,
    On,
    Off,
}

#[derive(Debug, Clone)]
struct Node {
    bound: f64,
    /// Deltas relative to the all-Free root: (flat index, state).
    entry_fixes: Vec<(usize, Entry)>,
    /// D bound rows: (platform, lb, ub).
    d_fixes: Vec<(usize, f64, f64)>,
    depth: usize,
}

impl PartialEq for Node {
    fn eq(&self, o: &Self) -> bool {
        self.bound == o.bound
    }
}
impl Eq for Node {}
impl PartialOrd for Node {
    fn partial_cmp(&self, o: &Self) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Node {
    fn cmp(&self, o: &Self) -> Ordering {
        o.bound.total_cmp(&self.bound) // min-heap
    }
}

/// The paper's MILP partitioner.
#[derive(Debug, Clone, Default)]
pub struct MilpPartitioner {
    pub cfg: MilpConfig,
}

impl MilpPartitioner {
    pub fn new(cfg: MilpConfig) -> MilpPartitioner {
        MilpPartitioner { cfg }
    }

    /// Build the node LP over A (reduced), F_L and D.
    fn build_lp(
        models: &ModelSet,
        budget: Option<f64>,
        entries: &[Entry],
        d_bounds: &[(f64, f64)],
    ) -> Problem {
        let (mu, tau) = (models.mu, models.tau);
        let mut p = Problem::new();
        // A variables.
        let a_vars: Vec<_> = (0..mu * tau)
            .map(|k| {
                let (i, j) = (k / tau, k % tau);
                let ub = if entries[k] == Entry::Off { 0.0 } else { 1.0 };
                p.cont(&format!("a_{i}_{j}"), 0.0, ub)
            })
            .collect();
        let f_l = p.cont("F_L", 0.0, f64::INFINITY);
        let d_vars: Vec<_> = (0..mu)
            .map(|i| p.cont(&format!("d_{i}"), d_bounds[i].0, d_bounds[i].1))
            .collect();

        // Task coverage: Σ_i A_ij = 1.
        for j in 0..tau {
            let terms: Vec<_> = (0..mu).map(|i| (a_vars[i * tau + j], 1.0)).collect();
            p.constrain(terms, Cmp::Eq, 1.0);
        }
        // Latency + quantum rows.
        for i in 0..mu {
            let mut terms = Vec::with_capacity(tau + 1);
            let mut gamma_const = 0.0;
            for j in 0..tau {
                let k = i * tau + j;
                match entries[k] {
                    Entry::Off => {}
                    Entry::On => {
                        gamma_const += models.setup_secs(i, j);
                        terms.push((a_vars[k], models.work_secs(i, j)));
                    }
                    Entry::Free => {
                        terms.push((a_vars[k], models.work_secs(i, j) + models.setup_secs(i, j)));
                    }
                }
            }
            // G_L,i - F_L <= -gamma_const.
            let mut lat_terms = terms.clone();
            lat_terms.push((f_l, -1.0));
            p.constrain(lat_terms, Cmp::Le, -gamma_const);
            // G_L,i - rho_i D_i <= -gamma_const.
            let mut q_terms = terms;
            q_terms.push((d_vars[i], -models.cost[i].quantum_secs));
            p.constrain(q_terms, Cmp::Le, -gamma_const);
        }
        // Budget: Σ_i π_i D_i <= C_k.
        if let Some(c_k) = budget {
            let terms: Vec<_> = (0..mu)
                .map(|i| (d_vars[i], models.cost[i].rate_per_quantum()))
                .collect();
            p.constrain(terms, Cmp::Le, c_k);
        }
        p.minimize(vec![(f_l, 1.0)]);
        p
    }

    /// Balanced allocation over a platform subset: inverse-solo-latency
    /// proportional shares among `subset`, zero elsewhere.
    fn balanced_over(models: &ModelSet, subset: &[usize]) -> Allocation {
        let mut weights = vec![0.0; models.mu];
        for &i in subset {
            weights[i] = 1.0 / models.solo_latency(i).max(1e-12);
        }
        Allocation::proportional(models.mu, models.tau, &weights)
    }

    /// Quantum-aware repair: if `alloc`'s true (ceiled) cost exceeds the
    /// budget, greedily evict platforms — each step trying every candidate
    /// eviction, rebalancing the survivors, and keeping the feasible result
    /// with the smallest makespan (or, while still infeasible, the smallest
    /// cost). This is the incumbent generator that makes B&B pruning
    /// effective at paper scale (2048 indicator entries).
    fn repair_to_budget(models: &ModelSet, alloc: Allocation, budget: f64) -> Option<Allocation> {
        if models.total_cost(&alloc) <= budget + 1e-9 {
            return Some(alloc);
        }
        let mut subset = alloc.used_platforms();
        let mut best_feasible: Option<(f64, Allocation)> = None;
        while subset.len() > 1 {
            let mut step: Option<(bool, f64, usize, Allocation)> = None; // (feasible, key, evict, alloc)
            for &cand in &subset {
                let rest: Vec<usize> = subset.iter().copied().filter(|&i| i != cand).collect();
                let a = Self::mct_over(models, &rest);
                let (lat, cost) = models.evaluate(&a);
                let feasible = cost <= budget + 1e-9;
                let key = if feasible { lat } else { cost };
                let better = match &step {
                    None => true,
                    Some((sf, sk, _, _)) => (feasible && !sf) || (feasible == *sf && key < *sk),
                };
                if better {
                    step = Some((feasible, key, cand, a));
                }
            }
            let (feasible, key, evict, a) = step?;
            subset.retain(|&i| i != evict);
            if feasible
                && best_feasible
                    .as_ref()
                    .map(|(l, _)| key < *l)
                    .unwrap_or(true)
            {
                best_feasible = Some((key, a));
            }
        }
        best_feasible.map(|(_, a)| a)
    }

    /// γ-aware greedy (MCT) whole-task assignment restricted to a platform
    /// subset: each task (largest work first) goes to the subset platform
    /// that finishes it earliest. Unlike proportional splits, this charges
    /// every task's setup γ exactly once — which at paper scale (128 × 40 s
    /// FPGA configuration) is the difference between good and useless seeds.
    fn mct_over(models: &ModelSet, subset: &[usize]) -> Allocation {
        let mut order: Vec<usize> = (0..models.tau).collect();
        // Largest work first (LPT) gives MCT a better packing.
        order.sort_by(|&a, &b| {
            let wa: f64 = subset.iter().map(|&i| models.work_secs(i, a)).sum();
            let wb: f64 = subset.iter().map(|&i| models.work_secs(i, b)).sum();
            wb.total_cmp(&wa)
        });
        let mut ready = vec![0.0f64; models.mu];
        let mut alloc = Allocation::zero(models.mu, models.tau);
        for &j in &order {
            let &best = subset
                .iter()
                .min_by(|&&a, &&b| {
                    let ca = ready[a] + models.work_secs(a, j) + models.setup_secs(a, j);
                    let cb = ready[b] + models.work_secs(b, j) + models.setup_secs(b, j);
                    ca.total_cmp(&cb)
                })
                .unwrap();
            ready[best] += models.work_secs(best, j) + models.setup_secs(best, j);
            alloc.set(best, j, 1.0);
        }
        alloc
    }

    /// Subset-ladder seeds: γ-aware MCT assignments over the top-k fastest
    /// platforms for every k — strong initial incumbents at any budget.
    fn ladder_seeds(models: &ModelSet) -> Vec<Allocation> {
        let mut order: Vec<usize> = (0..models.mu).collect();
        order.sort_by(|&a, &b| models.solo_latency(a).total_cmp(&models.solo_latency(b)));
        (1..=models.mu)
            .flat_map(|k| {
                [Self::mct_over(models, &order[..k]), Self::balanced_over(models, &order[..k])]
            })
            .collect()
    }

    /// Extract the allocation part of an LP point.
    fn extract_alloc(models: &ModelSet, x: &[f64]) -> Allocation {
        let (mu, tau) = (models.mu, models.tau);
        let mut a = Allocation::zero(mu, tau);
        for i in 0..mu {
            for j in 0..tau {
                let v = x[i * tau + j].clamp(0.0, 1.0);
                if v > ALLOC_TOL {
                    a.set(i, j, v);
                }
            }
        }
        // LP equality rows guarantee column sums ~1; normalise residuals.
        let _ = a.normalise();
        a
    }

    /// Solve Eq. 4; returns the detailed outcome.
    pub fn solve(&self, models: &ModelSet, budget: Option<f64>) -> Result<MilpOutcome> {
        let start = Instant::now();
        let (mu, tau) = (models.mu, models.tau);

        // Initial incumbent from the heuristic (and C_L as a fallback).
        let mut incumbent: Option<(Allocation, f64, f64)> = None; // (alloc, makespan, cost)
        let consider = |alloc: Allocation,
                            incumbent: &mut Option<(Allocation, f64, f64)>| {
            if alloc.validate().is_err() {
                return;
            }
            let (lat, cost) = models.evaluate(&alloc);
            if budget.map(|b| cost <= b + 1e-9).unwrap_or(true)
                && incumbent.as_ref().map(|(_, l, _)| lat < *l).unwrap_or(true)
            {
                *incumbent = Some((alloc, lat, cost));
            }
        };
        if let Ok(h) = HeuristicPartitioner::default().partition(models, budget) {
            consider(h, &mut incumbent);
        }
        consider(lower_cost_bound(models).1, &mut incumbent);
        for seed in Self::ladder_seeds(models) {
            if let Some(b) = budget {
                if let Some(repaired) = Self::repair_to_budget(models, seed.clone(), b) {
                    consider(repaired, &mut incumbent);
                }
            }
            consider(seed, &mut incumbent);
        }

        let root_entries = vec![Entry::Free; mu * tau];
        let root_d = vec![(0.0, f64::INFINITY); mu];
        let mut heap = BinaryHeap::new();
        heap.push(Node { bound: 0.0, entry_fixes: vec![], d_fixes: vec![], depth: 0 });
        let mut nodes = 0usize;
        let mut best_bound: f64 = 0.0;
        // Smallest bound of any subtree dropped on a node-LP solver failure
        // (+inf when none): caps the reported bound so a drained frontier
        // cannot claim optimality over unexplored mass.
        let mut dropped_bound = f64::INFINITY;

        let workers = self.cfg.workers.max(1);
        loop {
            // Stop rules run at round boundaries, against the frontier
            // minimum. Every explored subtree is represented in the heap by
            // its unexpanded children, so the heap top IS the provable
            // lower bound at this point — unlike a running max of popped
            // bounds, which a same-round sibling's children can undercut.
            let Some(top) = heap.peek().map(|n| n.bound) else { break };
            if let Some((_, inc_lat, _)) = &incumbent {
                if top >= inc_lat * (1.0 - self.cfg.rel_gap) {
                    // Everything left is within tolerance of the incumbent.
                    best_bound = top;
                    break;
                }
            }
            if nodes >= self.cfg.max_nodes
                || start.elapsed().as_secs_f64() > self.cfg.time_limit_secs
            {
                best_bound = top;
                break;
            }

            // Collect a round: up to `workers` nodes, never overshooting
            // the node budget (multi-worker runs explore exactly as many
            // nodes as sequential ones before stopping).
            let cap = workers.min(self.cfg.max_nodes - nodes);
            let mut round = Vec::with_capacity(cap);
            while round.len() < cap {
                let Some(node) = heap.pop() else { break };
                nodes += 1;

                // Materialise node state.
                let mut entries = root_entries.clone();
                for &(k, s) in &node.entry_fixes {
                    entries[k] = s;
                }
                let mut d_bounds = root_d.clone();
                for &(i, lb, ub) in &node.d_fixes {
                    d_bounds[i] = (lb, ub);
                }
                round.push((node, entries, d_bounds));
            }

            // The round's node LPs are independent — solve them
            // concurrently (they dominate wall-clock). Everything below
            // stays sequential in node order, so the search is
            // deterministic for a fixed `workers` count.
            let lps: Vec<Problem> = round
                .iter()
                .map(|(_, entries, d_bounds)| {
                    Self::build_lp(models, budget, entries, d_bounds)
                })
                .collect();
            let sols = if workers == 1 {
                lps.iter().map(simplex::solve).collect()
            } else {
                parallel_map(lps, workers, |lp| simplex::solve(&lp))
            };

            for ((node, entries, d_bounds), sol) in round.into_iter().zip(sols) {
                match sol.status {
                    LpStatus::Optimal => {}
                    LpStatus::Infeasible => continue,
                    LpStatus::Unbounded | LpStatus::IterLimit => {
                        // Solver failure: the subtree is dropped unexplored,
                        // so its inherited bound keeps capping the reported
                        // bound (the incumbent stays correct regardless).
                        dropped_bound = dropped_bound.min(node.bound);
                        continue;
                    }
                }
                if let Some((_, inc_lat, _)) = &incumbent {
                    if sol.obj >= inc_lat * (1.0 - self.cfg.rel_gap) {
                        continue; // dominated subtree
                    }
                }

                // True-semantics evaluation -> possible incumbent. If the LP
                // point overshoots the budget through quantum ceilings,
                // repair it (evict quantum-wasting platforms) before
                // considering.
                let alloc = Self::extract_alloc(models, &sol.x);
                if let Some(b) = budget {
                    if models.total_cost(&alloc) > b + 1e-9 {
                        if let Some(repaired) =
                            Self::repair_to_budget(models, alloc.clone(), b)
                        {
                            consider(repaired, &mut incumbent);
                        }
                    }
                }
                consider(alloc, &mut incumbent);

                // Pick the branching decision.
                // 1) Largest γ-undercharge among fractional Free entries.
                let mut best_entry: Option<(usize, f64)> = None;
                for i in 0..mu {
                    for j in 0..tau {
                        let k = i * tau + j;
                        if entries[k] == Entry::Free {
                            let a = sol.x[k];
                            if a > ALLOC_TOL && a < 1.0 - ALLOC_TOL {
                                let undercharge = models.setup_secs(i, j) * (1.0 - a);
                                if undercharge > best_entry.map(|(_, u)| u).unwrap_or(1e-9) {
                                    best_entry = Some((k, undercharge));
                                }
                            }
                        }
                    }
                }
                if let Some((k, _)) = best_entry {
                    for state in [Entry::Off, Entry::On] {
                        let mut fixes = node.entry_fixes.clone();
                        fixes.push((k, state));
                        heap.push(Node {
                            bound: sol.obj,
                            entry_fixes: fixes,
                            d_fixes: node.d_fixes.clone(),
                            depth: node.depth + 1,
                        });
                    }
                    continue;
                }
                // 2) No γ-undercharge left: close the quantum gap if the
                //    budget is the blocker (fractional D with binding cost).
                if budget.is_some() {
                    let d_offset = mu * tau + 1;
                    let frac_d = (0..mu)
                        .map(|i| (i, sol.x[d_offset + i]))
                        .filter(|(_, d)| (d - d.round()).abs() > 1e-6)
                        .max_by(|a, b| {
                            let fa = (a.1 - a.1.floor()).min(a.1.ceil() - a.1);
                            let fb = (b.1 - b.1.floor()).min(b.1.ceil() - b.1);
                            fa.total_cmp(&fb)
                        });
                    if let Some((i, d)) = frac_d {
                        let (lb, ub) = d_bounds[i];
                        for (nlb, nub) in [(lb, d.floor()), (d.ceil(), ub)] {
                            if nlb <= nub {
                                let mut d_fixes = node.d_fixes.clone();
                                d_fixes.push((i, nlb, nub));
                                heap.push(Node {
                                    bound: sol.obj,
                                    entry_fixes: node.entry_fixes.clone(),
                                    d_fixes,
                                    depth: node.depth + 1,
                                });
                            }
                        }
                        continue;
                    }
                }
                // Fully integral node: its LP obj is exact; nothing to do.
            }
        }

        if heap.is_empty() {
            // Frontier fully drained: the only unexplored mass sits in
            // subtrees dropped on solver failure, so the bound closes onto
            // the incumbent when the search truly exhausted.
            if let Some((_, lat, _)) = &incumbent {
                best_bound = dropped_bound.min(*lat);
            }
        }

        match incumbent {
            Some((alloc, makespan, cost)) => {
                // The incumbent proves the optimum <= makespan, and any
                // solver-failure drop caps the bound from below — so the
                // reported bound never exceeds either (a gap-stop's
                // frontier top can).
                let bound = best_bound.min(dropped_bound).min(makespan);
                let gap = if makespan > 0.0 {
                    ((makespan - bound) / makespan).max(0.0)
                } else {
                    0.0
                };
                Ok(MilpOutcome { alloc, makespan, cost, bound, gap, nodes })
            }
            None => Err(CloudshapesError::solver(format!(
                "MILP: no feasible allocation within budget {budget:?} \
                 (C_L = {:.4})",
                lower_cost_bound(models).0
            ))),
        }
    }
}

impl Partitioner for MilpPartitioner {
    fn name(&self) -> &str {
        "milp"
    }

    fn partition(&self, models: &ModelSet, budget: Option<f64>) -> Result<Allocation> {
        self.solve(models, budget).map(|o| o.alloc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{CostModel, LatencyModel};

    fn models() -> ModelSet {
        let l = |b, g| LatencyModel::new(b, g);
        // fast+hourly vs slow+minutely (the CPU-quantum effect).
        ModelSet::new(
            vec![
                l(1e-4, 5.0),
                l(1e-4, 5.0),
                l(1e-3, 0.5),
                l(1e-3, 0.5),
            ],
            vec![CostModel::new(3600.0, 1.0).unwrap(), CostModel::new(60.0, 0.5).unwrap()],
            vec![2_000_000, 1_000_000],
            vec!["fast-hourly".into(), "slow-minutely".into()],
        )
    }

    #[test]
    fn unconstrained_beats_or_matches_heuristic() {
        let m = models();
        let milp = MilpPartitioner::default().solve(&m, None).unwrap();
        let heur = HeuristicPartitioner::upper_bound_allocation(&m);
        assert!(milp.makespan <= m.makespan(&heur) + 1e-6, "{milp:?}");
        assert!(milp.alloc.validate().is_ok());
        assert!(milp.gap <= 0.05, "gap {}", milp.gap);
    }

    #[test]
    fn respects_budget_with_true_ceiling_cost() {
        let m = models();
        for budget in [0.1, 0.3, 0.6, 1.5] {
            match MilpPartitioner::default().solve(&m, Some(budget)) {
                Ok(out) => {
                    assert!(out.cost <= budget + 1e-9, "budget {budget}: {out:?}");
                    assert!((m.total_cost(&out.alloc) - out.cost).abs() < 1e-9);
                }
                Err(_) => {
                    // Only acceptable if even C_L exceeds the budget.
                    assert!(lower_cost_bound(&m).0 > budget, "budget {budget}");
                }
            }
        }
    }

    #[test]
    fn tighter_budget_never_decreases_makespan() {
        let m = models();
        let p = MilpPartitioner::default();
        let loose = p.solve(&m, Some(2.0)).unwrap();
        let tight = p.solve(&m, Some(0.5)).unwrap(); // C_L is ~$0.43
        assert!(tight.makespan >= loose.makespan - 1e-6);
    }

    #[test]
    fn bound_is_below_makespan() {
        let m = models();
        let out = MilpPartitioner::default().solve(&m, Some(1.0)).unwrap();
        assert!(out.bound <= out.makespan + 1e-9);
        assert!(out.gap >= 0.0);
    }

    #[test]
    fn single_platform_problem_is_trivial() {
        let l = LatencyModel::new(1e-3, 1.0);
        let m = ModelSet::new(
            vec![l, l],
            vec![CostModel::new(60.0, 0.5).unwrap()],
            vec![10_000, 20_000],
            vec!["only".into()],
        );
        let out = MilpPartitioner::default().solve(&m, None).unwrap();
        assert_eq!(out.alloc.used_platforms(), vec![0]);
        // 10 + 1 + 20 + 1 = 32 s.
        assert!((out.makespan - 32.0).abs() < 1e-6);
    }

    #[test]
    fn impossible_budget_is_an_error() {
        let m = models();
        assert!(MilpPartitioner::default().solve(&m, Some(1e-9)).is_err());
    }

    #[test]
    fn multi_worker_rounds_match_sequential_quality() {
        let m = models();
        let seq = MilpPartitioner::default();
        let par = MilpPartitioner::new(MilpConfig { workers: 4, ..Default::default() });
        for budget in [None, Some(0.6), Some(1.5)] {
            let a = seq.solve(&m, budget).unwrap();
            let b = par.solve(&m, budget).unwrap();
            assert!(b.alloc.validate().is_ok());
            if let Some(c) = budget {
                assert!(b.cost <= c + 1e-9, "budget {c}: {b:?}");
            }
            // Rounds only widen exploration; on this small instance both
            // searches close the same incumbent.
            assert!(
                (a.makespan - b.makespan).abs() <= 0.01 * a.makespan.max(1e-9),
                "budget {budget:?}: seq {} vs par {}",
                a.makespan,
                b.makespan
            );
        }
    }

    #[test]
    fn milp_uses_short_quantum_platform_when_heuristic_wont() {
        // The §IV.C.2 effect: a budget that fits several cheap minutely
        // quanta but not an extra hourly quantum.
        let m = models();
        let p = MilpPartitioner::default();
        let b = 1.2; // one hourly quantum ($1) + a few minutely cents
        let milp = p.solve(&m, Some(b)).unwrap();
        let heur = HeuristicPartitioner::default()
            .partition(&m, Some(b))
            .map(|a| m.makespan(&a));
        if let Ok(heur_makespan) = heur {
            assert!(
                milp.makespan <= heur_makespan + 1e-6,
                "milp {} vs heuristic {heur_makespan}",
                milp.makespan
            );
        }
    }
}

impl MilpPartitioner {
    /// Expose the root node LP for profiling (perf benches / examples).
    pub fn debug_root_lp(models: &ModelSet, budget: Option<f64>) -> Problem {
        let entries = vec![Entry::Free; models.mu * models.tau];
        let d_bounds = vec![(0.0, f64::INFINITY); models.mu];
        Self::build_lp(models, budget, &entries, &d_bounds)
    }
}
