//! ε-constraint trade-off generation (§III.C, after Kirlik & Sayın):
//! sweep cost budgets C_k between the lower bound C_L (cheapest single
//! platform) and the upper bound C_U (cost of the unconstrained
//! latency-optimal partition), solve each constrained problem, and filter
//! the resulting (cost, latency) points to the Pareto-optimal set.

use crate::api::error::Result;
use crate::coordinator::allocation::Allocation;
use crate::coordinator::objectives::ModelSet;

use super::partitioner::{lower_cost_bound, Partitioner};

/// One point of a trade-off curve.
#[derive(Debug, Clone)]
pub struct TradeoffPoint {
    /// The budget C_k this point was solved under (None = unconstrained).
    pub budget: Option<f64>,
    pub alloc: Allocation,
    /// Model-predicted makespan, seconds.
    pub latency: f64,
    /// Model-predicted billed cost, $.
    pub cost: f64,
}

/// A generated trade-off curve plus its bounds.
#[derive(Debug, Clone)]
pub struct TradeoffCurve {
    pub partitioner: String,
    pub c_lower: f64,
    pub c_upper: f64,
    /// All evaluated points, cheapest first (not necessarily Pareto).
    pub points: Vec<TradeoffPoint>,
}

impl TradeoffCurve {
    /// The Pareto-optimal (non-dominated) subset, cheapest first.
    pub fn pareto_front(&self) -> Vec<&TradeoffPoint> {
        let mut sorted: Vec<&TradeoffPoint> = self.points.iter().collect();
        sorted.sort_by(|a, b| a.cost.total_cmp(&b.cost).then(a.latency.total_cmp(&b.latency)));
        let mut front: Vec<&TradeoffPoint> = Vec::new();
        let mut best_latency = f64::INFINITY;
        for p in sorted {
            if p.latency < best_latency - 1e-12 {
                best_latency = p.latency;
                front.push(p);
            }
        }
        front
    }

    /// Point whose budget is the median of the sweep (Table IV's C_k row).
    pub fn median_point(&self) -> Option<&TradeoffPoint> {
        if self.points.is_empty() {
            return None;
        }
        Some(&self.points[self.points.len() / 2])
    }

    /// Cheapest and fastest points.
    pub fn cheapest(&self) -> Option<&TradeoffPoint> {
        self.points
            .iter()
            .min_by(|a, b| a.cost.total_cmp(&b.cost))
    }

    pub fn fastest(&self) -> Option<&TradeoffPoint> {
        self.points
            .iter()
            .min_by(|a, b| a.latency.total_cmp(&b.latency))
    }
}

/// Sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    /// Number of budget levels between C_L and C_U (inclusive).
    pub levels: usize,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig { levels: 11 }
    }
}

/// Generate the latency-cost trade-off for `partitioner` (§III.C steps 1-3).
pub fn sweep(
    partitioner: &dyn Partitioner,
    models: &ModelSet,
    cfg: &SweepConfig,
) -> Result<TradeoffCurve> {
    assert!(cfg.levels >= 2, "need at least the two bounds");
    // Step 1: upper cost bound from the unconstrained latency optimum.
    let fast_alloc = partitioner.partition(models, None)?;
    let (fast_latency, c_upper) = models.evaluate(&fast_alloc);
    // Step 2: lower cost bound.
    let (c_lower, cheap_alloc) = lower_cost_bound(models);
    let (cheap_latency, cheap_cost) = models.evaluate(&cheap_alloc);

    let mut points = vec![TradeoffPoint {
        budget: Some(c_lower),
        alloc: cheap_alloc,
        latency: cheap_latency,
        cost: cheap_cost,
    }];
    // Step 3: iterate C_k between the bounds.
    for k in 1..cfg.levels - 1 {
        let c_k = c_lower + (c_upper - c_lower) * k as f64 / (cfg.levels - 1) as f64;
        match partitioner.partition(models, Some(c_k)) {
            Ok(alloc) => {
                let (latency, cost) = models.evaluate(&alloc);
                points.push(TradeoffPoint { budget: Some(c_k), alloc, latency, cost });
            }
            Err(_) => continue, // infeasible level (can happen near C_L)
        }
    }
    points.push(TradeoffPoint {
        budget: None,
        alloc: fast_alloc,
        latency: fast_latency,
        cost: c_upper,
    });
    Ok(TradeoffCurve { partitioner: partitioner.name().to_string(), c_lower, c_upper, points })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::partitioner::{HeuristicPartitioner, MilpPartitioner};
    use crate::models::{CostModel, LatencyModel};

    fn models() -> ModelSet {
        let l = |b, g| LatencyModel::new(b, g);
        ModelSet::new(
            vec![
                l(1e-4, 5.0),
                l(1e-4, 5.0),
                l(1e-3, 0.5),
                l(1e-3, 0.5),
                l(5e-3, 0.2),
                l(5e-3, 0.2),
            ],
            vec![
                CostModel::new(3600.0, 1.0).unwrap(),
                CostModel::new(600.0, 0.4).unwrap(),
                CostModel::new(60.0, 0.3).unwrap(),
            ],
            vec![5_000_000, 2_000_000],
            vec!["p0".into(), "p1".into(), "p2".into()],
        )
    }

    #[test]
    fn heuristic_sweep_brackets_budgets() {
        let m = models();
        let curve = sweep(&HeuristicPartitioner::default(), &m, &SweepConfig::default()).unwrap();
        assert!(curve.c_lower <= curve.c_upper);
        assert!(curve.points.len() >= 2);
        for p in &curve.points {
            assert!(p.alloc.validate().is_ok());
            if let Some(b) = p.budget {
                assert!(p.cost <= b + 1e-9, "cost {} over budget {b}", p.cost);
            }
        }
    }

    #[test]
    fn pareto_front_is_monotone() {
        let m = models();
        let curve = sweep(&MilpPartitioner::default(), &m, &SweepConfig { levels: 6 }).unwrap();
        let front = curve.pareto_front();
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[0].cost <= w[1].cost);
            assert!(w[0].latency >= w[1].latency);
        }
    }

    #[test]
    fn milp_dominates_heuristic_pointwise() {
        // At every heuristic budget, MILP's latency is <= heuristic's
        // (the paper's headline claim, "performs no worse in the worst case").
        let m = models();
        let hcurve =
            sweep(&HeuristicPartitioner::default(), &m, &SweepConfig { levels: 5 }).unwrap();
        let milp = MilpPartitioner::default();
        for p in &hcurve.points {
            if let Some(b) = p.budget {
                let out = milp.solve(&m, Some(b)).unwrap();
                assert!(
                    out.makespan <= p.latency + 1e-6,
                    "budget {b}: milp {} vs heuristic {}",
                    out.makespan,
                    p.latency
                );
            }
        }
    }

    #[test]
    fn curve_accessors() {
        let m = models();
        let curve = sweep(&HeuristicPartitioner::default(), &m, &SweepConfig::default()).unwrap();
        let cheap = curve.cheapest().unwrap();
        let fast = curve.fastest().unwrap();
        assert!(cheap.cost <= fast.cost + 1e-9);
        assert!(fast.latency <= cheap.latency + 1e-9);
        assert!(curve.median_point().is_some());
    }
}
