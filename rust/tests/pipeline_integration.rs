//! Whole-pipeline integration: config -> experiment -> benchmark -> partition
//! -> sweep -> execute -> report, on the quick preset (no artifacts needed),
//! plus CLI and serve round-trips.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use cloudshapes::api::SessionBuilder;
use cloudshapes::cli;
use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::{self, Experiment};
use cloudshapes::util::json::Json;

fn quick() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::quick();
    cfg.milp.time_limit_secs = 2.0;
    cfg.sweep.levels = 4;
    cfg
}

#[test]
fn full_pipeline_quick() {
    let session = SessionBuilder::from_config(quick()).build().unwrap();

    // Fitted models are usable and close to nominal for heavyweight pairs.
    let m = session.models();
    assert_eq!((m.mu, m.tau), (3, 8));

    // Partition with both approaches, execute both, compare predictions.
    for name in ["milp", "heuristic"] {
        let ev = session.evaluate_with(Some(name), None).unwrap();
        let (p, rep) = (&ev.partition, &ev.execution);
        assert_eq!(rep.failures, 0);
        let lat_err = (rep.makespan_secs - p.predicted_latency_s).abs() / p.predicted_latency_s;
        assert!(
            lat_err < 0.35,
            "{name}: predicted {} measured {}",
            p.predicted_latency_s,
            rep.makespan_secs
        );
        assert!(rep.cost <= p.predicted_cost * 1.5 + 0.1);
        // All tasks priced.
        assert!(rep.prices.iter().all(Option::is_some));
    }
}

#[test]
fn sweep_and_reports_quick() {
    let session = SessionBuilder::from_config(quick()).build().unwrap();
    let curve = session.pareto_frontier().unwrap();
    assert!(curve.points.len() >= 2);
    assert!(curve.c_lower <= curve.c_upper + 1e-9);

    // Table/figure generators run end to end on the same experiment.
    let e = session.experiment();
    let t2 = report::tables::table2_for(e);
    assert_eq!(t2.n_rows(), 3);
    let t4 = report::table4(session.models(), &session.config().milp).unwrap();
    assert!(t4.render().contains("Cheapest (C_L)"));
    let (plot, points) = report::fig2(e, &[2.0, 5.0]);
    assert!(!points.is_empty());
    assert!(plot.render().contains("Fig. 2"));
}

#[test]
fn config_files_in_repo_parse() {
    for name in ["configs/paper.toml", "configs/quick.toml", "configs/native.toml"] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
        let cfg = ExperimentConfig::load(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(cfg.sweep.levels >= 2, "{name}");
    }
}

#[test]
fn cli_quick_commands() {
    let run = |s: &str| cli::main(&s.split_whitespace().map(String::from).collect::<Vec<_>>());
    assert_eq!(run("table 1"), 0);
    assert_eq!(run("table 3"), 0);
    assert_eq!(run("info --quick"), 0);
    assert_eq!(run("partition --quick --partitioner min-min"), 0);
    assert_eq!(run("pareto --quick --partitioner heuristic --levels 3"), 0);
    assert_eq!(run("run --quick --partitioner heuristic"), 0);
    assert_eq!(run("bogus"), 1);
}

#[test]
fn serve_tcp_roundtrip() {
    let session = Arc::new(SessionBuilder::from_config(quick()).build().unwrap());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || serve_until_shutdown(listener, session));

    let ask = |line: &str| -> Json {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut r = BufReader::new(s);
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };
    let pong = ask(r#"{"v":1,"op":"ping"}"#);
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
    let part = ask(r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":100.0}"#);
    assert_eq!(part.get("ok"), Some(&Json::Bool(true)), "{}", part.to_string_compact());
    // Unversioned requests are rejected with a structured protocol error.
    let legacy = ask(r#"{"op":"ping"}"#);
    assert_eq!(legacy.get("ok"), Some(&Json::Bool(false)));
    let bye = ask(r#"{"v":1,"op":"shutdown"}"#);
    assert_eq!(bye.get("shutdown"), Some(&Json::Bool(true)));
    server.join().unwrap().unwrap();
}

#[test]
fn workload_scales_are_consistent() {
    // Paper-scale sanity: the default workload on the default cluster has
    // the paper's order-of-magnitude makespans (thousands of seconds on the
    // cheapest platform), so Table IV comparisons are meaningful.
    let cfg = ExperimentConfig::default();
    let e = Experiment::build(cfg).unwrap();
    let (c_l, alloc) = cloudshapes::coordinator::partitioner::lower_cost_bound(e.models());
    let lat = e.models().makespan(&alloc);
    assert!(
        (1_000.0..200_000.0).contains(&lat),
        "cheapest-platform makespan {lat} out of paper range"
    );
    assert!(c_l > 0.5 && c_l < 100.0, "C_L {c_l} out of range");
}
