//! Online-scheduler integration tests (ISSUE 5 acceptance):
//!
//! - staggered arrivals with deadline SLOs both complete within their SLOs;
//! - an injected straggler drives the incremental re-fit, which triggers a
//!   re-solve whose predicted makespan strictly improves on the stale warm
//!   incumbent, and model error tightens between the first and last epoch;
//! - `cancel` releases in-flight capacity back to the queue;
//! - per-family re-fit (ISSUE 10): on a cluster where basket chunks
//!   secretly cost 4x the modelled FLOP rate, the family-aware fit cuts
//!   the latency-prediction error vs the single pooled line and predicts
//!   the realised makespan better;
//! - `serve --scheduler` handles 8 concurrent `submit`s spanning all six
//!   payoff families with mixed deadline/budget SLOs over TCP, all
//!   meeting their SLOs.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cloudshapes::api::{SessionBuilder, TradeoffSession};
use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::executor::execute_static;
use cloudshapes::coordinator::partitioner::HeuristicPartitioner;
use cloudshapes::coordinator::scheduler::{
    JobSpec, JobState, OnlineScheduler, SchedulerConfig, Slo,
};
use cloudshapes::coordinator::{ExecutorConfig, ModelSet, Partitioner};
use cloudshapes::models::{OnlineLatencyFit, PlatformPrior};
use cloudshapes::platforms::sim::{SimConfig, SimPlatform};
use cloudshapes::platforms::spec::small_cluster;
use cloudshapes::platforms::{ChunkCtx, Cluster, Platform};
use cloudshapes::util::json::Json;
use cloudshapes::workload::{generate, GeneratorConfig, Payoff};

/// Nominal (spec-derived) priors — deliberately blind to hidden factors.
fn nominal_priors(cluster: &Cluster) -> Vec<PlatformPrior> {
    cluster
        .specs()
        .iter()
        .map(|s| PlatformPrior {
            throughput_flops: s.app_gflops.max(1e-9) * 1e9,
            setup_secs: s.setup_secs,
        })
        .collect()
}

fn exact_cluster() -> Cluster {
    Cluster::simulated(&small_cluster(), &SimConfig::exact(), 21).unwrap()
}

/// Unconstrained heuristic makespan of a job's tasks on nominal models —
/// used to size epochs so tests reliably span several of them.
fn nominal_makespan(cluster: &Cluster, spec: &JobSpec) -> f64 {
    let workload = cloudshapes::workload::Workload::new(spec.tasks.clone());
    let models = ModelSet::from_specs(&cluster.specs(), &workload);
    let alloc = HeuristicPartitioner::default().partition(&models, None).unwrap();
    models.makespan(&alloc)
}

fn start_scheduler(cluster: Cluster, cfg: SchedulerConfig) -> OnlineScheduler {
    let priors = nominal_priors(&cluster);
    OnlineScheduler::start(cluster, priors, ExecutorConfig::default(), cfg, || {
        Ok(Box::new(HeuristicPartitioner::default()))
    })
    .unwrap()
}

fn wait_terminal(s: &OnlineScheduler, id: u64) -> cloudshapes::coordinator::JobStatus {
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let st = s.job_status(id).expect("job tracked");
        if st.state.is_terminal() {
            return st;
        }
        assert!(Instant::now() < deadline, "job {id} never finished: {st:?}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

#[test]
fn staggered_jobs_with_conflicting_deadlines_meet_their_slos() {
    let cluster = exact_cluster();
    let job_a = JobSpec::generate(None, 4, 0.01, 11, Slo::Deadline(1e7)).unwrap();
    // Epochs sized so job A spans several of them — job B genuinely arrives
    // mid-service and competes for the same fast platforms.
    let epoch = (nominal_makespan(&cluster, &job_a) / 5.0).max(1.0);
    let s = start_scheduler(
        cluster,
        SchedulerConfig { enabled: true, epoch_secs: epoch, ..Default::default() },
    );
    let a = s.submit(job_a).unwrap();
    // Stagger: wait until A has made epoch progress before B arrives.
    let deadline = Instant::now() + Duration::from_secs(60);
    while s.stats().epochs < 1 {
        assert!(Instant::now() < deadline, "first epoch never ran");
        std::thread::sleep(Duration::from_millis(2));
    }
    let b = s
        .submit(JobSpec::generate(Some(Payoff::Asian), 2, 0.02, 13, Slo::Deadline(1e7)).unwrap())
        .unwrap();
    let st_a = wait_terminal(&s, a);
    let st_b = wait_terminal(&s, b);
    for (id, st) in [(a, &st_a), (b, &st_b)] {
        assert_eq!(st.state, JobState::Done, "job {id}: {st:?}");
        assert_eq!(st.slo_met, Some(true), "job {id} missed its SLO: {st:?}");
        assert_eq!(st.sims_done, st.sims_total);
        assert!(st.prices.iter().all(Option::is_some), "job {id} unpriced tasks");
        assert!(st.cost > 0.0);
    }
    // B really arrived later, in virtual time too.
    assert!(st_b.arrival_s > 0.0, "B must arrive after the clock moved");
    assert!(st_a.epochs >= 2, "A was meant to span epochs: {st_a:?}");
    let stats = s.stats();
    assert_eq!(stats.completed, 2);
    assert!(stats.epochs >= 2);
    s.shutdown();
}

#[test]
fn straggler_refit_resolves_and_tightens_model_error() {
    // The GPU (platform 1 — nominally the fastest, so the first plan leans
    // on it hardest) is a hidden 5x straggler: nominal priors (and
    // therefore the first epoch's models) are blind to it.
    let specs = small_cluster();
    let mut platforms: Vec<Arc<dyn Platform>> = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let p = if i == 1 {
            SimPlatform::with_hidden_factor(spec.clone(), SimConfig::exact(), 21, 5.0)
        } else {
            SimPlatform::new(spec.clone(), SimConfig::exact(), 21 + i as u64)
        };
        platforms.push(Arc::new(p));
    }
    let cluster = Cluster::new(platforms).unwrap();
    let job = JobSpec::generate(None, 5, 0.01, 17, Slo::Deadline(1e9)).unwrap();
    let epoch = (nominal_makespan(&cluster, &job) / 5.0).max(1.0);
    let s = start_scheduler(
        cluster,
        SchedulerConfig { enabled: true, epoch_secs: epoch, ..Default::default() },
    );
    let id = s.submit(job).unwrap();
    let st = wait_terminal(&s, id);
    assert_eq!(st.state, JobState::Done, "{st:?}");
    assert!(st.epochs >= 2, "straggler run must span epochs: {st:?}");

    let stats = s.stats();
    // Re-fit tightening (acceptance): the first epoch solves against models
    // blind to the 5x straggler; by the last epoch the windowed re-fit has
    // absorbed it and prediction error has measurably collapsed.
    let first = stats.first_model_error.expect("epochs produced chunks");
    let last = stats.last_model_error.expect("epochs produced chunks");
    assert!(
        first > 0.05,
        "first epoch should mispredict the hidden straggler, got {first}"
    );
    assert!(
        last < first * 0.6,
        "re-fit must tighten model error: first {first} -> last {last}"
    );
    // The drift triggered at least one re-solve whose predicted makespan
    // strictly improves on the stale warm incumbent under the SAME
    // refreshed models.
    let improved = stats.records.iter().any(|r| {
        r.resolved
            && r.warm_makespan_s
                .map(|w| r.predicted_makespan_s < w * 0.99)
                .unwrap_or(false)
    });
    assert!(
        improved,
        "no re-solve improved on the warm incumbent: {:?}",
        stats.records
    );
    s.shutdown();
}

#[test]
fn cancel_releases_capacity_back_to_the_queue() {
    let cluster = exact_cluster();
    // Job A is enormous (hundreds of epochs); B is tiny. One in-flight slot.
    let job_a = JobSpec::generate(None, 4, 0.004, 19, Slo::Deadline(1e12)).unwrap();
    let epoch = (nominal_makespan(&cluster, &job_a) / 200.0).max(0.5);
    let s = start_scheduler(
        cluster,
        SchedulerConfig {
            enabled: true,
            epoch_secs: epoch,
            max_in_flight: 1,
            ..Default::default()
        },
    );
    let a = s.submit(job_a).unwrap();
    let b = s
        .submit(JobSpec::generate(None, 1, 0.05, 23, Slo::Budget(1000.0)).unwrap())
        .unwrap();
    // A occupies the only slot; B waits queued.
    let deadline = Instant::now() + Duration::from_secs(60);
    loop {
        let st_a = s.job_status(a).unwrap();
        if st_a.state == JobState::Running {
            break;
        }
        assert!(Instant::now() < deadline, "A never started");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(s.job_status(b).unwrap().state, JobState::Queued);
    // Cancel A: its slot must return to the queue and B must run.
    assert_eq!(s.cancel(a), Some(true));
    let st_b = wait_terminal(&s, b);
    assert_eq!(st_b.state, JobState::Done);
    assert_eq!(st_b.slo_met, Some(true));
    // B only left the queue once A was terminal (cancel happens-before
    // admission under the scheduler lock).
    let st_a = s.job_status(a).unwrap();
    assert_eq!(st_a.state, JobState::Cancelled);
    assert_eq!(st_a.slo_met, Some(false));
    assert!(st_a.finished_s.is_some());
    let stats = s.stats();
    assert_eq!(stats.cancelled, 1);
    assert_eq!(stats.completed, 1);
    s.shutdown();
}

#[test]
fn per_family_refit_beats_the_single_line_on_a_mixed_exotic_queue() {
    // ISSUE 10 acceptance: basket chunks secretly cost 4x the FLOP rate the
    // models assume while barrier chunks run on-model. Fed identical
    // observations, the per-family fit must (a) cut the mean relative
    // chunk-latency prediction error vs the single pooled line and (b)
    // predict the realised makespan of the resulting plan better.
    let specs = small_cluster();
    let mut factors = [1.0; Payoff::COUNT];
    factors[Payoff::Basket.index()] = 4.0;
    let platforms: Vec<Arc<dyn Platform>> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| -> Arc<dyn Platform> {
            Arc::new(SimPlatform::with_family_factors(
                s.clone(),
                SimConfig::exact(),
                21 + i as u64,
                factors,
            ))
        })
        .collect();
    let cluster = Cluster::new(platforms).unwrap();
    let mut mix = [0.0; Payoff::COUNT];
    mix[Payoff::Barrier.index()] = 0.5;
    mix[Payoff::Basket.index()] = 0.5;
    let workload = generate(&GeneratorConfig {
        n_tasks: 12,
        seed: 31,
        accuracy: 0.02,
        payoff_mix: mix,
        step_choices: vec![64],
        ..GeneratorConfig::default()
    });
    assert!(workload.tasks.iter().any(|t| t.payoff == Payoff::Barrier));
    assert!(workload.tasks.iter().any(|t| t.payoff == Payoff::Basket));

    // Warm chunks carry no setup, so each observation is pure work time —
    // exactly what `observe` expects after the scheduler's γ subtraction.
    let priors = nominal_priors(&cluster);
    let mut family = OnlineLatencyFit::new(priors.clone(), 64);
    let mut single = OnlineLatencyFit::single_line(priors, 64);
    const CHUNK: u64 = 1 << 15;
    let warm = ChunkCtx { offset: 0, prior_sims: CHUNK };
    for i in 0..cluster.len() {
        for t in &workload.tasks {
            for _ in 0..2 {
                let out = cluster.platform(i).execute(t, CHUNK, 3, warm);
                assert!(out.error.is_none(), "{:?}", out.error);
                let flops = t.flops_per_path() * CHUNK as f64;
                family.observe(i, t.payoff, flops, out.latency_secs);
                single.observe(i, t.payoff, flops, out.latency_secs);
            }
        }
    }

    // (a) Warm-chunk latency prediction error over every (platform, task)
    // pairing. The exact simulator has no noise, so the family fit should
    // recover each family's realised rate essentially exactly while the
    // pooled line mis-prices both sides of the 4x split.
    let mean_err = |fit: &OnlineLatencyFit| {
        let mut total = 0.0;
        let mut count = 0usize;
        for i in 0..cluster.len() {
            for t in &workload.tasks {
                let truth = cluster.platform(i).execute(t, CHUNK, 5, warm).latency_secs;
                let pred = fit.model(i, t.payoff, t.flops_per_path()).beta * CHUNK as f64;
                total += (pred - truth).abs() / truth;
                count += 1;
            }
        }
        total / count as f64
    };
    let err_family = mean_err(&family);
    let err_single = mean_err(&single);
    assert!(err_family < 1e-6, "family fit should nail the exact sim, got {err_family}");
    assert!(err_single > 0.15, "pooled line should mis-price a 4x family split, got {err_single}");

    // (b) Build a ModelSet from each fit, plan on the family-aware one and
    // execute for real: the family-aware makespan prediction must sit near
    // the realised value, the single-line one visibly off it.
    let cost_models: Vec<_> = specs.iter().map(|s| s.cost_model()).collect();
    let names: Vec<String> = specs.iter().map(|s| s.name.clone()).collect();
    let model_set = |fit: &OnlineLatencyFit| {
        let mut latency = Vec::with_capacity(cluster.len() * workload.len());
        for i in 0..cluster.len() {
            for t in &workload.tasks {
                latency.push(fit.model(i, t.payoff, t.flops_per_path()));
            }
        }
        ModelSet::new(
            latency,
            cost_models.clone(),
            workload.tasks.iter().map(|t| t.n_sims).collect(),
            names.clone(),
        )
        .with_task_families(workload.tasks.iter().map(|t| t.payoff).collect())
    };
    let m_family = model_set(&family);
    let m_single = model_set(&single);
    let alloc = HeuristicPartitioner::default().partition(&m_family, None).unwrap();
    let realised = execute_static(&cluster, &workload, &alloc, &ExecutorConfig::default())
        .unwrap()
        .makespan_secs;
    let gap_family = (m_family.makespan(&alloc) - realised).abs() / realised;
    let gap_single = (m_single.makespan(&alloc) - realised).abs() / realised;
    assert!(
        gap_family < 0.10,
        "family-aware prediction should track the realised makespan: {gap_family}"
    );
    assert!(
        gap_single > 2.0 * gap_family,
        "single-line prediction should be visibly worse: family {gap_family} vs single {gap_single}"
    );
}

#[test]
fn scheduler_completes_mixed_exotics_with_family_refit_disabled() {
    // The `family_refit = false` ablation path must still drive an exotic
    // job through the full scheduler loop (single pooled line per
    // platform, as before ISSUE 10).
    let cluster = exact_cluster();
    let job = JobSpec::generate(Some(Payoff::Basket), 2, 0.05, 41, Slo::Deadline(1e9)).unwrap();
    let s = start_scheduler(
        cluster,
        SchedulerConfig { enabled: true, family_refit: false, ..Default::default() },
    );
    let id = s.submit(job).unwrap();
    let st = wait_terminal(&s, id);
    assert_eq!(st.state, JobState::Done, "{st:?}");
    assert_eq!(st.slo_met, Some(true));
    assert!(st.prices.iter().all(Option::is_some));
    s.shutdown();
}

// ───────────────────────── serve --scheduler, end to end ────────────────

struct Server {
    addr: std::net::SocketAddr,
    handle: Option<std::thread::JoinHandle<cloudshapes::Result<()>>>,
}

fn start_scheduler_server() -> Server {
    let mut cluster = ExperimentConfig::quick().cluster;
    cluster.sim = SimConfig::exact();
    let session: TradeoffSession = SessionBuilder::quick()
        .cluster(cluster)
        .partitioner("heuristic")
        .scheduler(SchedulerConfig { enabled: true, ..Default::default() })
        .build()
        .unwrap();
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let session = Arc::new(session);
    let handle = std::thread::spawn(move || serve_until_shutdown(listener, session));
    Server { addr, handle: Some(handle) }
}

impl Server {
    fn ask(&self, line: &str) -> Json {
        let mut s = TcpStream::connect(self.addr).unwrap();
        s.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut r = BufReader::new(s);
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }

    fn shutdown(mut self) {
        let bye = self.ask(r#"{"v":1,"op":"shutdown"}"#);
        assert_eq!(bye.get("shutdown"), Some(&Json::Bool(true)));
        self.handle.take().unwrap().join().unwrap().unwrap();
    }
}

#[test]
fn serve_scheduler_handles_eight_concurrent_mixed_slo_submits() {
    let server = Arc::new(start_scheduler_server());
    // Every payoff family crosses the wire (the exotics exercise the same
    // `Payoff::parse` dispatch, so no serve-layer change was needed).
    let payoffs = Payoff::NAMES;
    // 8 concurrent clients, mixed deadline/budget SLOs. Client 0 streams.
    let mut handles = Vec::new();
    for k in 0..8usize {
        let server = Arc::clone(&server);
        handles.push(std::thread::spawn(move || -> u64 {
            let slo = if k % 2 == 0 {
                r#""deadline":1e9"#.to_string()
            } else {
                r#""budget":1000"#.to_string()
            };
            let payoff = payoffs[k % payoffs.len()];
            if k == 0 {
                // Streaming submit: event lines, then the final response.
                let mut s = TcpStream::connect(server.addr).unwrap();
                let req = format!(
                    r#"{{"v":1,"op":"submit","tasks":2,"payoff":"{payoff}","seed":{k},{slo},"stream":true}}"#
                );
                s.write_all(format!("{req}\n").as_bytes()).unwrap();
                let mut r = BufReader::new(s);
                let mut events = 0usize;
                loop {
                    let mut line = String::new();
                    r.read_line(&mut line).unwrap();
                    let json = Json::parse(line.trim()).unwrap();
                    if json.get("ok").is_some() {
                        assert_eq!(json.get("ok"), Some(&Json::Bool(true)), "{line}");
                        assert_eq!(json.get("status").unwrap().as_str(), Some("done"));
                        assert_eq!(json.get("slo_met"), Some(&Json::Bool(true)));
                        // `events` may be 0 when the job finishes between
                        // submit and the first poll; any events seen must
                        // have been job events (asserted below).
                        let _ = events;
                        return json.get("job_id").unwrap().as_u64().unwrap();
                    }
                    assert_eq!(json.get("event").unwrap().as_str(), Some("job"));
                    events += 1;
                }
            }
            let req = format!(
                r#"{{"v":1,"op":"submit","tasks":2,"payoff":"{payoff}","seed":{k},{slo}}}"#
            );
            let resp = server.ask(&req);
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string_compact());
            resp.get("job_id").unwrap().as_u64().unwrap()
        }));
    }
    let ids: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(ids.len(), 8);

    // Every job completes within its SLO.
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let resp = server.ask(r#"{"v":1,"op":"jobs"}"#);
        let jobs = resp.get("jobs").unwrap().as_arr().unwrap();
        assert_eq!(jobs.len(), 8);
        let done = jobs
            .iter()
            .filter(|j| j.get("status").unwrap().as_str() == Some("done"))
            .count();
        let active = jobs.iter().any(|j| {
            matches!(j.get("status").unwrap().as_str(), Some("queued") | Some("running"))
        });
        if !active {
            assert_eq!(done, 8, "{}", resp.to_string_compact());
            for j in jobs {
                assert_eq!(j.get("slo_met"), Some(&Json::Bool(true)), "{}", j.to_string_compact());
                assert!(j.get("cost").unwrap().as_f64().unwrap() > 0.0);
            }
            break;
        }
        assert!(Instant::now() < deadline, "jobs never finished");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Ping reports the scheduler counters (and the re-fit trajectory).
    let ping = server.ask(r#"{"v":1,"op":"ping"}"#);
    let sched = ping.get("scheduler").expect("scheduler stats in ping");
    assert_eq!(sched.get("submitted").unwrap().as_u64(), Some(8));
    assert_eq!(sched.get("completed").unwrap().as_u64(), Some(8));
    assert!(sched.get("epochs").unwrap().as_u64().unwrap() >= 1);
    assert!(
        sched.get("model_error_last").is_some(),
        "{}",
        ping.to_string_compact()
    );
    match Arc::try_unwrap(server) {
        Ok(server) => server.shutdown(),
        Err(_) => panic!("server still shared"),
    }
}
