//! Serve-plane integration tests: the epoll/poll event loop, lp1 framing,
//! consistent-hash cache sharding, admission control, read deadlines and
//! deterministic teardown. Complements `serve_protocol.rs` (which pins the
//! request/response *semantics*); this file pins the *transport* behaviour
//! the async sharded rewrite introduced — and proves `[serve] shards = 1`
//! reproduces the legacy single-cache path byte for byte.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cloudshapes::api::{SessionBuilder, TradeoffSession};
use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::partitioner::MilpConfig;
use cloudshapes::platforms::sim::SimConfig;
use cloudshapes::serve::{lp1_frame, lp1_read, quantize, ServeConfig, ShardMap};
use cloudshapes::util::json::Json;

struct Server {
    addr: std::net::SocketAddr,
    handle: Option<std::thread::JoinHandle<cloudshapes::Result<()>>>,
}

fn serve_session(session: TradeoffSession) -> Server {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let session = Arc::new(session);
    let handle = std::thread::spawn(move || serve_until_shutdown(listener, session));
    Server { addr, handle: Some(handle) }
}

/// A noise-free (byte-reproducible) session with the given serve config.
fn exact_server(serve: ServeConfig) -> Server {
    let mut cluster = ExperimentConfig::quick().cluster;
    cluster.sim = SimConfig::exact();
    serve_session(
        SessionBuilder::quick()
            .cluster(cluster)
            .milp(MilpConfig { time_limit_secs: 2.0, ..Default::default() })
            .budget_sweep(3)
            .serve(serve)
            .build()
            .unwrap(),
    )
}

impl Server {
    /// One newline-framed request on a fresh connection.
    fn ask(&self, line: &str) -> Json {
        let mut s = TcpStream::connect(self.addr).unwrap();
        s.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut r = BufReader::new(s);
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }

    fn shutdown(mut self) {
        let bye = self.ask(r#"{"v":1,"op":"shutdown"}"#);
        assert_eq!(bye.get("shutdown"), Some(&Json::Bool(true)));
        self.handle.take().unwrap().join().unwrap().unwrap();
    }
}

fn error_kind(resp: &Json) -> Option<&str> {
    resp.get("error")?.get("kind")?.as_str()
}

fn error_message(resp: &Json) -> &str {
    resp.get("error").unwrap().get("message").unwrap().as_str().unwrap()
}

// ---------------------------------------------------------------------------
// Consistent-hash shard map (pure, no server needed)
// ---------------------------------------------------------------------------

/// A spread of (strategy, budget) keys shaped like real serve traffic.
fn traffic_keys() -> Vec<(String, Option<f64>)> {
    let mut keys = Vec::new();
    for strategy in ["milp", "heuristic", "proportional", "random"] {
        keys.push((strategy.to_string(), None));
        for i in 0..2_500u32 {
            keys.push((strategy.to_string(), Some(0.37 + f64::from(i) * 13.91)));
        }
    }
    keys
}

#[test]
fn every_key_routes_to_exactly_one_stable_shard() {
    let map = ShardMap::new(4);
    let again = ShardMap::new(4);
    let mut seen = vec![0usize; 4];
    for (strategy, budget) in traffic_keys() {
        let shard = map.shard_for(&strategy, quantize(budget));
        assert!(shard < 4, "shard {shard} out of range for ({strategy}, {budget:?})");
        // Routing is a pure function of the key and the shard count.
        assert_eq!(shard, map.shard_for(&strategy, quantize(budget)));
        assert_eq!(shard, again.shard_for(&strategy, quantize(budget)));
        seen[shard] += 1;
    }
    // The ring spreads load: no shard is starved or hot-spotted to nothing.
    for (i, n) in seen.iter().enumerate() {
        assert!(*n > 0, "shard {i} owns no keys: {seen:?}");
    }
}

#[test]
fn resharding_moves_a_bounded_fraction_of_keys() {
    let before = ShardMap::new(4);
    let after = ShardMap::new(5);
    let keys = traffic_keys();
    let moved = keys
        .iter()
        .filter(|(s, b)| before.shard_for(s, quantize(*b)) != after.shard_for(s, quantize(*b)))
        .count();
    let fraction = moved as f64 / keys.len() as f64;
    // Consistent hashing: growing 4 -> 5 shards should remap ~1/5 of the
    // keyspace; modulo hashing would remap ~4/5. Allow vnode variance.
    assert!(
        fraction <= 0.35,
        "{moved}/{} keys moved ({fraction:.2}) — ring is not consistent",
        keys.len()
    );
    assert!(fraction > 0.0, "no keys moved; the new shard is unreachable");
}

// ---------------------------------------------------------------------------
// Sharded cache vs the legacy single-cache path
// ---------------------------------------------------------------------------

#[test]
fn sharded_cache_is_byte_identical_to_single_cache_path() {
    // Same noise-free experiment served twice: shards = 1 is the legacy
    // single-cache layout, shards = 4 the sharded one. Every response must
    // match byte for byte (JSON is key-ordered, the executor is
    // seed-deterministic, so any divergence is a cache-routing bug).
    let single = exact_server(ServeConfig { shards: 1, ..ServeConfig::default() });
    let sharded = exact_server(ServeConfig { shards: 4, ..ServeConfig::default() });

    let requests = [
        r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#,
        r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#,
        r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":1000000.0}"#,
        r#"{"v":1,"op":"pareto","partitioner":"heuristic"}"#,
        r#"{"v":1,"op":"batch","partitioner":"heuristic","budgets":[null,1000000.0]}"#,
        r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":null}"#,
    ];
    for req in requests {
        let a = single.ask(req).to_string_compact();
        let b = sharded.ask(req).to_string_compact();
        assert_eq!(a, b, "sharded response diverged for {req}");
        assert!(a.contains("\"ok\":true"), "{req} -> {a}");
    }

    // Both planes served everything from coherent caches: the repeat
    // evaluate and the batch nulls are hits in both layouts.
    for server in [&single, &sharded] {
        let cache = server.ask(r#"{"v":1,"op":"ping"}"#);
        let hits = cache.get("cache").unwrap().get("hits").unwrap().as_u64().unwrap();
        assert!(hits >= 2, "expected cache hits, got {hits}");
    }

    single.shutdown();
    sharded.shutdown();
}

// ---------------------------------------------------------------------------
// lp1 framing
// ---------------------------------------------------------------------------

#[test]
fn lp1_negotiation_roundtrip_matches_newline_payloads() {
    let server = exact_server(ServeConfig::default());

    let mut stream = TcpStream::connect(server.addr).unwrap();
    // The negotiating request is still newline-framed; its response (and
    // everything after) is length-prefixed.
    stream.write_all(b"{\"v\":1,\"op\":\"ping\",\"framing\":\"lp1\"}\n").unwrap();
    let pong = lp1_read(&mut stream).unwrap();
    let pong = Json::parse(&pong).unwrap();
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));

    // Subsequent requests are lp1 in both directions; the payload bytes
    // must equal what a newline-framed client sees.
    let req = r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#;
    stream.write_all(&lp1_frame(req)).unwrap();
    let via_lp1 = lp1_read(&mut stream).unwrap();
    let via_newline = server.ask(req).to_string_compact();
    assert_eq!(Json::parse(&via_lp1).unwrap().to_string_compact(), via_newline);

    // Pipelined lp1 frames come back in order on one connection.
    stream.write_all(&lp1_frame(r#"{"v":1,"op":"ping"}"#)).unwrap();
    stream.write_all(&lp1_frame(r#"{"v":1,"op":"specs"}"#)).unwrap();
    let first = Json::parse(&lp1_read(&mut stream).unwrap()).unwrap();
    let second = Json::parse(&lp1_read(&mut stream).unwrap()).unwrap();
    assert_eq!(first.get("pong"), Some(&Json::Bool(true)));
    assert!(second.get("specs").is_some());

    server.shutdown();
}

#[test]
fn unknown_framing_value_is_a_typed_error_and_mode_is_unchanged() {
    let server = exact_server(ServeConfig::default());

    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream.write_all(b"{\"v\":1,\"op\":\"ping\",\"framing\":\"lp2\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let resp = Json::parse(resp.trim()).unwrap();
    assert_eq!(error_kind(&resp), Some("protocol"));
    assert!(error_message(&resp).contains("framing"), "{resp:?}");

    // The connection survives, still newline-framed.
    stream.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert_eq!(Json::parse(resp.trim()).unwrap().get("ok"), Some(&Json::Bool(true)));

    server.shutdown();
}

// ---------------------------------------------------------------------------
// Read deadlines and request-size limits (slow-loris defence)
// ---------------------------------------------------------------------------

#[test]
fn slow_loris_partial_request_times_out_with_typed_error() {
    let server = exact_server(ServeConfig { read_timeout_secs: 0.3, ..ServeConfig::default() });

    let mut stream = TcpStream::connect(server.addr).unwrap();
    // A request that never completes: bytes arrive, the newline never does.
    stream.write_all(b"{\"v\":1,\"op\":\"pi").unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let start = Instant::now();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let err = Json::parse(resp.trim()).unwrap();
    assert_eq!(error_kind(&err), Some("protocol"));
    assert!(error_message(&err).contains("timed out"), "{err:?}");
    assert!(
        start.elapsed() >= Duration::from_millis(250),
        "timed out suspiciously early: {:?}",
        start.elapsed()
    );
    // ... and the server hangs up afterwards.
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF, got {rest:?}");

    server.shutdown();
}

#[test]
fn oversized_requests_are_rejected_in_both_framings() {
    let server = exact_server(ServeConfig { max_request_bytes: 256, ..ServeConfig::default() });

    // Newline mode: the buffer blows the limit before any newline shows up.
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let huge = format!("{{\"v\":1,\"op\":\"ping\",\"pad\":\"{}\"}}\n", "x".repeat(512));
    stream.write_all(huge.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let err = Json::parse(resp.trim()).unwrap();
    assert_eq!(error_kind(&err), Some("protocol"));
    assert!(error_message(&err).contains("max_request_bytes"), "{err:?}");
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF after oversize");

    // lp1 mode: a length header past the limit is rejected from the header
    // alone — the server never waits for (or buffers) the payload.
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.write_all(b"{\"v\":1,\"op\":\"ping\",\"framing\":\"lp1\"}\n").unwrap();
    let pong = lp1_read(&mut stream).unwrap();
    assert!(pong.contains("\"pong\":true"), "{pong}");
    stream.write_all(&(1u32 << 24).to_be_bytes()).unwrap();
    let err = Json::parse(&lp1_read(&mut stream).unwrap()).unwrap();
    assert_eq!(error_kind(&err), Some("protocol"));
    assert!(error_message(&err).contains("lp1"), "{err:?}");
    let mut rest = Vec::new();
    assert_eq!(stream.read_to_end(&mut rest).unwrap(), 0, "expected EOF after bad length");

    server.shutdown();
}

#[test]
fn frame_error_flushes_inflight_pipelined_response_before_close() {
    // Regression: a frame error used to close the connection as soon as the
    // flush buffer was empty, dropping responses still parked in reorder
    // slots or in flight at a shard. One write delivers an uncached
    // evaluate followed by an oversized junk frame: the evaluate is in
    // flight when the junk trips the size limit, and BOTH the evaluate
    // response and the (later-sequenced) error must arrive before EOF.
    let server = exact_server(ServeConfig { max_request_bytes: 256, ..ServeConfig::default() });

    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut burst = Vec::new();
    burst.extend_from_slice(
        b"{\"v\":1,\"op\":\"evaluate\",\"partitioner\":\"heuristic\",\"budget\":null}\n",
    );
    burst.extend_from_slice(&vec![b'x'; 512]); // no newline: oversize junk
    stream.write_all(&burst).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let evaluated = Json::parse(line.trim()).unwrap();
    assert_eq!(
        evaluated.get("ok"),
        Some(&Json::Bool(true)),
        "in-flight response lost to the frame error: {}",
        evaluated.to_string_compact()
    );

    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let err = Json::parse(line.trim()).unwrap();
    assert_eq!(error_kind(&err), Some("protocol"));
    assert!(error_message(&err).contains("max_request_bytes"), "{err:?}");

    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF, got {rest:?}");

    server.shutdown();
}

#[test]
fn read_timeout_flushes_inflight_pipelined_response_before_close() {
    // Same guarantee for the slow-loris sweep: the timeout's typed error
    // queues BEHIND the in-flight evaluate and both flush before close.
    let server = exact_server(ServeConfig { read_timeout_secs: 0.3, ..ServeConfig::default() });

    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream
        .write_all(b"{\"v\":1,\"op\":\"evaluate\",\"partitioner\":\"heuristic\",\"budget\":null}\n{\"v\":1")
        .unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let evaluated = Json::parse(line.trim()).unwrap();
    assert_eq!(
        evaluated.get("ok"),
        Some(&Json::Bool(true)),
        "in-flight response lost to the read timeout: {}",
        evaluated.to_string_compact()
    );

    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let err = Json::parse(line.trim()).unwrap();
    assert_eq!(error_kind(&err), Some("protocol"));
    assert!(error_message(&err).contains("timed out"), "{err:?}");

    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF, got {rest:?}");

    server.shutdown();
}

#[test]
fn idle_connections_outlive_the_read_deadline_by_default() {
    // Compat with the legacy thread-per-connection server: a connection
    // idle BETWEEN requests is never reaped unless idle_timeout_secs opts
    // in — read_timeout_secs only guards partial frames.
    let server = exact_server(ServeConfig { read_timeout_secs: 0.3, ..ServeConfig::default() });

    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    for pause in [Duration::ZERO, Duration::from_millis(800)] {
        std::thread::sleep(pause);
        stream.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "idle connection was closed after {pause:?}");
        assert_eq!(Json::parse(line.trim()).unwrap().get("ok"), Some(&Json::Bool(true)));
    }

    server.shutdown();
}

#[test]
fn idle_timeout_reaps_quiet_connections_when_enabled() {
    let server =
        exact_server(ServeConfig { idle_timeout_secs: 0.3, ..ServeConfig::default() });

    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    stream.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(line.trim()).unwrap().get("ok"), Some(&Json::Bool(true)));

    // Go quiet: the server closes the connection silently (EOF, no error).
    let start = Instant::now();
    let mut rest = String::new();
    assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "expected EOF, got {rest:?}");
    assert!(
        start.elapsed() >= Duration::from_millis(250),
        "reaped suspiciously early: {:?}",
        start.elapsed()
    );

    server.shutdown();
}

// ---------------------------------------------------------------------------
// Admission control
// ---------------------------------------------------------------------------

#[test]
fn overload_sheds_pipelined_requests_with_structured_errors() {
    let server =
        exact_server(ServeConfig { shards: 1, max_inflight: 1, ..ServeConfig::default() });

    // One write delivers an uncached pareto sweep followed by a burst of
    // pings. With an in-flight budget of 1, the pings that land while the
    // sweep occupies the budget are shed — and because responses flush in
    // request order, the reply sequence is still exactly one line per
    // request, in order, on the same connection.
    const PINGS: usize = 64;
    let mut burst = String::from(r#"{"v":1,"op":"pareto","partitioner":"heuristic"}"#);
    burst.push('\n');
    for _ in 0..PINGS {
        burst.push_str(r#"{"v":1,"op":"ping"}"#);
        burst.push('\n');
    }
    let mut stream = TcpStream::connect(server.addr).unwrap();
    stream.write_all(burst.as_bytes()).unwrap();

    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let pareto = Json::parse(line.trim()).unwrap();
    assert_eq!(pareto.get("ok"), Some(&Json::Bool(true)), "{}", pareto.to_string_compact());

    let mut shed = 0usize;
    for i in 0..PINGS {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection dropped at ping {i}");
        let resp = Json::parse(line.trim()).unwrap_or_else(|e| panic!("ping {i}: {e}: {line}"));
        match error_kind(&resp) {
            None => assert_eq!(resp.get("pong"), Some(&Json::Bool(true)), "ping {i}"),
            Some("overload") => {
                assert!(error_message(&resp).contains("retry"), "{resp:?}");
                shed += 1;
            }
            Some(other) => panic!("ping {i}: unexpected error kind {other}: {resp:?}"),
        }
    }
    assert!(shed >= 1, "no pings were shed despite max_inflight = 1");

    // The sheds are observable in the metrics plane.
    let metrics = server.ask(r#"{"v":1,"op":"metrics","filter":"serve_"}"#).to_string_compact();
    assert!(metrics.contains("serve_shed_total"), "missing shed counter: {metrics}");

    // The connection is still healthy after shedding.
    stream.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(line.trim()).unwrap().get("ok"), Some(&Json::Bool(true)));

    server.shutdown();
}

// ---------------------------------------------------------------------------
// Deterministic teardown and shutdown draining
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
fn open_fd_count() -> usize {
    std::fs::read_dir("/proc/self/fd").unwrap().count()
}

#[cfg(target_os = "linux")]
#[test]
fn rapid_connect_disconnect_cycles_leak_no_fds() {
    let server = exact_server(ServeConfig::default());

    // Warm up (lazy fds: epoll, wake pipe, shard threads).
    for _ in 0..8 {
        let r = server.ask(r#"{"v":1,"op":"ping"}"#);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    }
    let baseline = open_fd_count();

    for cycle in 0..1_000 {
        let mut s = TcpStream::connect(server.addr).unwrap();
        if cycle % 2 == 0 {
            // Half the cycles complete a request; half just slam the door.
            s.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
            let mut r = BufReader::new(&mut s);
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            assert!(!line.is_empty(), "cycle {cycle}: dropped");
        }
        drop(s);
    }

    // The event loop closes its side of each connection deterministically;
    // give it a moment to observe the hangups, then the fd table must be
    // back at (or below) the warmed-up baseline.
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let now = open_fd_count();
        if now <= baseline {
            break;
        }
        assert!(Instant::now() < deadline, "fd count stuck at {now} (baseline {baseline})");
        std::thread::sleep(Duration::from_millis(20));
    }

    server.shutdown();
}

#[test]
fn shutdown_flushes_inflight_responses_before_closing() {
    let server = exact_server(ServeConfig::default());

    // Kick off an uncached solve on connection A, then immediately ask for
    // shutdown on connection B. The drain phase must flush A's response
    // before the listener closes.
    let mut a = TcpStream::connect(server.addr).unwrap();
    a.write_all(b"{\"v\":1,\"op\":\"evaluate\",\"partitioner\":\"heuristic\",\"budget\":null}\n")
        .unwrap();
    // Give the event loop a beat to read and dispatch A's frame — frames
    // still unread when the stop flag is observed are (by design) not
    // admitted during the drain.
    std::thread::sleep(Duration::from_millis(100));

    let mut b = TcpStream::connect(server.addr).unwrap();
    b.write_all(b"{\"v\":1,\"op\":\"shutdown\"}\n").unwrap();
    let mut rb = BufReader::new(b);
    let mut bye = String::new();
    rb.read_line(&mut bye).unwrap();
    assert_eq!(Json::parse(bye.trim()).unwrap().get("shutdown"), Some(&Json::Bool(true)));

    let mut ra = BufReader::new(a);
    let mut resp = String::new();
    ra.read_line(&mut resp).unwrap();
    assert!(!resp.is_empty(), "in-flight response lost at shutdown");
    let resp = Json::parse(resp.trim()).unwrap();
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}", resp.to_string_compact());

    let mut server = server;
    server.handle.take().unwrap().join().unwrap().unwrap();
}
