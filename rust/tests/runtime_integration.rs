//! Integration: AOT artifacts -> PJRT engine -> prices that match both the
//! native rust Threefry mirror and Black-Scholes. Requires `make artifacts`.

use std::path::PathBuf;

use cloudshapes::pricing::{blackscholes, combine, mc};
use cloudshapes::runtime::EngineHandle;
use cloudshapes::workload::option::{OptionTask, Payoff};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn engine() -> EngineHandle {
    EngineHandle::spawn(&artifact_dir()).expect("run `make artifacts` before cargo test")
}

fn task(payoff: Payoff) -> OptionTask {
    OptionTask {
        id: 3,
        payoff,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        barrier: 140.0,
        steps: 64, // matches the AOT variants for path-dependent payoffs
        target_accuracy: 0.05,
        n_sims: 1 << 16,
        ..OptionTask::default()
    }
}

#[test]
fn engine_loads_and_reports_platform() {
    let e = engine();
    assert_eq!(e.platform_name().to_lowercase(), "cpu");
    let payoffs = e.supported_payoffs();
    assert!(payoffs.contains(&Payoff::European));
    assert!(payoffs.contains(&Payoff::Asian));
    assert!(payoffs.contains(&Payoff::Barrier));
}

#[test]
fn european_price_matches_black_scholes() {
    let e = engine();
    let t = task(Payoff::European);
    let stats = e.price(&t, 1 << 17, 42).unwrap();
    assert!(stats.n >= 1 << 17);
    let est = combine(&stats, t.discount());
    let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
    assert!(
        (est.price - bs).abs() < 4.0 * est.std_error + 0.05,
        "pjrt {} ± {} vs bs {bs}",
        est.price,
        est.std_error
    );
}

#[test]
fn pjrt_matches_native_threefry_mirror_exactly() {
    // Same (task id, seed) stream, same chunk: the HLO and the rust mirror
    // must agree to f32 reduction tolerance.
    let e = engine();
    let t = task(Payoff::European);
    let pjrt = e.price(&t, 4096, 7).unwrap();
    let native = mc::simulate(&t, 7, 0, 4096);
    assert_eq!(pjrt.n, native.n);
    let rel = (pjrt.sum - native.sum).abs() / native.sum.abs().max(1.0);
    assert!(rel < 1e-4, "pjrt {} vs native {}", pjrt.sum, native.sum);
    let rel2 = (pjrt.sum_sq - native.sum_sq).abs() / native.sum_sq.abs().max(1.0);
    assert!(rel2 < 1e-4, "pjrt {} vs native {}", pjrt.sum_sq, native.sum_sq);
}

#[test]
fn path_dependent_payoffs_execute() {
    let e = engine();
    for payoff in [Payoff::Asian, Payoff::Barrier] {
        let t = task(payoff);
        let stats = e.price(&t, 4096, 1).unwrap();
        let est = combine(&stats, t.discount());
        assert!(est.price > 0.0 && est.price < t.spot, "{payoff:?}: {est:?}");
        // Both are dominated by the European call on the same terms.
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(est.price < bs + 4.0 * est.std_error, "{payoff:?}: {est:?} vs {bs}");
    }
}

#[test]
fn chunk_cover_overshoots_at_most_smallest_variant() {
    let e = engine();
    let t = task(Payoff::European);
    let stats = e.price(&t, 5000, 3).unwrap();
    // Smallest european variant is 4096: 5000 -> 4096 + 4096 = 8192.
    assert_eq!(stats.n, 8192);
}

#[test]
fn different_seeds_give_different_but_consistent_estimates() {
    let e = engine();
    let t = task(Payoff::European);
    let a = combine(&e.price(&t, 1 << 15, 1).unwrap(), t.discount());
    let b = combine(&e.price(&t, 1 << 15, 2).unwrap(), t.discount());
    assert_ne!(a.price, b.price);
    assert!((a.price - b.price).abs() < 6.0 * (a.std_error + b.std_error));
}
