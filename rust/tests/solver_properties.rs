//! Property tests for the MILP substrate via the in-tree `testing::prop`
//! harness (seed-replayable, size-ramped):
//!
//! * `milp/simplex.rs` — generated feasible LPs must come back `Optimal`
//!   with a primal-feasible point within tolerance;
//! * `milp/branch_bound.rs` — parallel (multi-worker) runs must match
//!   sequential runs **bit-for-bit** on objective and status at
//!   `rel_gap = 0`, and both must match brute force on binary instances.

use cloudshapes::milp::{self, BnbLimits, Cmp, LpStatus, MilpStatus, Problem};
use cloudshapes::testing::prop::{prop_assert, prop_check, Gen};

/// Packing-style LP: `x = 0` is always feasible (non-negative rows, positive
/// rhs) and every variable has a finite upper bound, so the LP is bounded —
/// the simplex must always report `Optimal`.
fn feasible_packing_lp(g: &mut Gen) -> Problem {
    let n = g.len(10);
    let m = g.usize(1, 6);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..n)
        .map(|i| {
            let ub = g.f64(0.5, 8.0);
            p.cont(&format!("x{i}"), 0.0, ub)
        })
        .collect();
    for _ in 0..m {
        let terms: Vec<_> = vars.iter().map(|v| (*v, g.f64(0.0, 4.0))).collect();
        p.constrain(terms, Cmp::Le, g.f64(0.5, 25.0));
    }
    p.minimize(vars.iter().map(|v| (*v, g.f64(-5.0, 5.0))).collect());
    p
}

#[test]
fn simplex_returns_primal_feasible_optima_on_generated_lps() {
    prop_check("simplex primal feasibility", 150, |g| {
        let p = feasible_packing_lp(g);
        let sol = milp::solve_lp(&p);
        prop_assert(sol.status == LpStatus::Optimal, &format!("status {:?}", sol.status))?;
        prop_assert(
            p.is_feasible(&sol.x, 1e-6),
            &format!("infeasible point {:?}", sol.x),
        )?;
        // x = 0 scores 0, so the minimum can't be positive.
        prop_assert(sol.obj <= 1e-9, &format!("obj {} above the x=0 value", sol.obj))?;
        prop_assert(
            (sol.obj - p.objective_value(&sol.x)).abs() <= 1e-9,
            "reported obj disagrees with the point",
        )
    });
}

/// Binary knapsack-style MILP with mixed-sign costs. Always feasible
/// (empty selection) and bounded.
fn random_binary_milp(g: &mut Gen) -> (Problem, Vec<f64>, Vec<f64>, f64) {
    let n = g.usize(3, 9);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..n).map(|i| p.bin(&format!("b{i}"))).collect();
    let w: Vec<f64> = (0..n).map(|_| g.f64(1.0, 5.0)).collect();
    let c: Vec<f64> = (0..n).map(|_| g.f64(-5.0, 5.0)).collect();
    let cap = g.f64(2.0, 14.0);
    p.constrain(vars.iter().zip(&w).map(|(b, w)| (*b, *w)).collect(), Cmp::Le, cap);
    p.minimize(vars.iter().zip(&c).map(|(b, c)| (*b, *c)).collect());
    (p, w, c, cap)
}

/// Bounded mixed-integer problem (ints with small ranges + continuous
/// vars), packing-style so `x = 0` stays feasible.
fn random_mixed_milp(g: &mut Gen) -> Problem {
    let n_int = g.usize(2, 6);
    let n_cont = g.usize(1, 3);
    let mut p = Problem::new();
    let mut vars = Vec::new();
    for i in 0..n_int {
        let ub = g.usize(1, 4) as f64;
        vars.push(p.int(&format!("z{i}"), 0.0, ub));
    }
    for i in 0..n_cont {
        let ub = g.f64(0.5, 6.0);
        vars.push(p.cont(&format!("x{i}"), 0.0, ub));
    }
    for _ in 0..g.usize(1, 4) {
        let terms: Vec<_> = vars.iter().map(|v| (*v, g.f64(0.0, 3.0))).collect();
        p.constrain(terms, Cmp::Le, g.f64(1.0, 20.0));
    }
    p.minimize(vars.iter().map(|v| (*v, g.f64(-4.0, 4.0))).collect());
    p
}

fn exact_limits(workers: usize) -> BnbLimits {
    BnbLimits { max_nodes: 500_000, rel_gap: 0.0, time_limit_secs: 60.0, workers }
}

/// Parallel == sequential (bit-for-bit objective) and == brute force.
#[test]
fn parallel_branch_bound_matches_sequential_and_bruteforce_on_binaries() {
    prop_check("bnb parallel == sequential (binary)", 30, |g| {
        let (p, w, c, cap) = random_binary_milp(g);
        let seq = milp::solve_milp(&p, &exact_limits(1));
        let par = milp::solve_milp(&p, &exact_limits(4));
        prop_assert(seq.status == MilpStatus::Optimal, &format!("seq {:?}", seq.status))?;
        prop_assert(par.status == MilpStatus::Optimal, &format!("par {:?}", par.status))?;
        prop_assert(
            (seq.obj + 0.0).to_bits() == (par.obj + 0.0).to_bits(),
            &format!("objective mismatch: seq {} vs par {}", seq.obj, par.obj),
        )?;
        prop_assert(p.is_feasible(&par.x, 1e-6), "parallel point infeasible")?;
        prop_assert(p.is_feasible(&seq.x, 1e-6), "sequential point infeasible")?;
        // Independent oracle: enumerate all subsets.
        let n = w.len();
        let mut best = f64::INFINITY;
        for mask in 0..(1u32 << n) {
            let weight: f64 = (0..n).filter(|i| mask >> i & 1 == 1).map(|i| w[i]).sum();
            if weight <= cap {
                let cost: f64 = (0..n).filter(|i| mask >> i & 1 == 1).map(|i| c[i]).sum();
                best = best.min(cost);
            }
        }
        prop_assert(
            (seq.obj - best).abs() < 1e-6,
            &format!("solver {} vs brute force {best}", seq.obj),
        )
    });
}

#[test]
fn parallel_branch_bound_matches_sequential_on_mixed_integers() {
    prop_check("bnb parallel == sequential (mixed)", 25, |g| {
        let p = random_mixed_milp(g);
        let seq = milp::solve_milp(&p, &exact_limits(1));
        let par = milp::solve_milp(&p, &exact_limits(3));
        prop_assert(
            seq.status == par.status,
            &format!("status mismatch: {:?} vs {:?}", seq.status, par.status),
        )?;
        prop_assert(seq.status == MilpStatus::Optimal, &format!("seq {:?}", seq.status))?;
        prop_assert(
            (seq.obj + 0.0).to_bits() == (par.obj + 0.0).to_bits(),
            &format!("objective mismatch: seq {} vs par {}", seq.obj, par.obj),
        )?;
        prop_assert(p.is_feasible(&par.x, 1e-6), "parallel point infeasible")
    });
}

/// The proven lower bound never exceeds the incumbent, sequential or not.
#[test]
fn bound_sandwiches_incumbent_across_worker_counts() {
    prop_check("bnb bound <= obj", 25, |g| {
        let (p, _, _, _) = random_binary_milp(g);
        for workers in [1, 2, 4] {
            let sol = milp::solve_milp(&p, &exact_limits(workers));
            prop_assert(
                sol.bound <= sol.obj + 1e-9,
                &format!("workers {workers}: bound {} above obj {}", sol.bound, sol.obj),
            )?;
            prop_assert(sol.gap <= 1e-12, &format!("workers {workers}: gap {}", sol.gap))?;
        }
        Ok(())
    });
}
