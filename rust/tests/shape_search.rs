//! Acceptance tests for the catalogue → composition → allocation pipeline:
//! pinned-testbed equivalence (the paper cluster as one catalogue
//! instantiation reproduces the fixed-cluster objectives), the
//! deadline-scenario cost win over the fixed-testbed heuristic, and the
//! spot-rental config plumbing.

use cloudshapes::api::SessionBuilder;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::{
    HeuristicPartitioner, MilpPartitioner, ModelSet, ShapeObjective, ShapeSearch, SweepConfig,
};
use cloudshapes::milp::BnbLimits;
use cloudshapes::models::{CostModel, LatencyModel};
use cloudshapes::platforms::catalogue::Catalogue;
use cloudshapes::platforms::spec::paper_cluster;
use cloudshapes::workload::{generate, GeneratorConfig};

#[test]
fn paper_testbed_is_the_pinned_catalogue_composition() {
    // The Table II testbed must be exactly Catalogue::paper() instantiated
    // at the pinned counts — same specs, same order, same billing terms.
    let catalogue = Catalogue::paper();
    let counts = catalogue.testbed_counts();
    assert_eq!(counts, vec![4, 8, 1, 1, 1, 1]);
    let specs = catalogue.instantiate(&counts, false).unwrap();
    assert_eq!(specs, paper_cluster());
    // Partition objectives over the composition match the fixed cluster's
    // to machine precision (they are the same specs).
    let w = generate(&GeneratorConfig::small(6, 0.02, 11));
    let fixed = ModelSet::from_specs(&paper_cluster(), &w);
    let composed = ModelSet::from_specs(&specs, &w);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&fixed);
    let (l_fixed, c_fixed) = fixed.evaluate(&alloc);
    let (l_comp, c_comp) = composed.evaluate(&alloc);
    assert!((l_fixed - l_comp).abs() < 1e-9);
    assert!((c_fixed - c_comp).abs() < 1e-9);
}

#[test]
fn pinned_counts_session_reproduces_default_session_objectives() {
    // A session whose [catalogue] counts pin the testbed composition (spot
    // off) must reproduce the default fixed-cluster session's evaluate
    // objectives to 1e-9 — same specs, same sim seeds, same benchmark.
    let base = SessionBuilder::quick().partitioner("heuristic").build().unwrap();
    let mut cfg = ExperimentConfig::quick();
    cfg.cluster.counts = Some(vec![1, 1, 1]); // the small testbed, pinned
    cfg.cluster.spot = false;
    let pinned = SessionBuilder::from_config(cfg).partitioner("heuristic").build().unwrap();

    let a = base.partition(None).unwrap();
    let b = pinned.partition(None).unwrap();
    assert!((a.predicted_latency_s - b.predicted_latency_s).abs() < 1e-9);
    assert!((a.predicted_cost - b.predicted_cost).abs() < 1e-9);
    assert_eq!(a.alloc, b.alloc);

    let ea = base.evaluate(None).unwrap();
    let eb = pinned.evaluate(None).unwrap();
    assert!((ea.execution.makespan_secs - eb.execution.makespan_secs).abs() < 1e-9);
    assert!((ea.execution.cost - eb.execution.cost).abs() < 1e-9);
    assert_eq!(ea.execution.preemptions, 0);
}

/// The deadline scenario: two rentable types whose quantum structure the
/// fixed-testbed heuristic cannot exploit. One task of 4500 s of work on
/// either type; `hourly` bills 3600-s quanta at $1/h, `minutely` 60-s
/// quanta at $1.2/h.
fn quantum_types() -> ModelSet {
    ModelSet::new(
        vec![LatencyModel::new(1.0, 0.0), LatencyModel::new(1.0, 0.0)],
        vec![
            CostModel::new(3600.0, 1.0).unwrap(),
            CostModel::new(60.0, 1.2).unwrap(),
        ],
        vec![4500],
        vec!["hourly".into(), "minutely".into()],
    )
}

#[test]
fn shape_search_undercuts_the_fixed_testbed_heuristic_at_a_deadline() {
    let types = quantum_types();
    let deadline = 3600.0;

    // Fixed testbed: one instance of each type, the paper heuristic, its
    // ε-constraint sweep; best billed cost among points meeting the
    // deadline.
    let testbed = types.replicate(&[1, 1]).unwrap();
    let heuristic = HeuristicPartitioner::default();
    let curve = cloudshapes::coordinator::sweep(
        &heuristic,
        &testbed,
        &SweepConfig { levels: 9 },
    )
    .unwrap();
    let fixed_best = curve
        .points
        .iter()
        .filter(|p| p.latency <= deadline + 1e-9)
        .map(|p| p.cost)
        .fold(f64::INFINITY, f64::min);
    assert!(fixed_best.is_finite(), "fixed testbed must meet the deadline somehow");

    // Shape search over the same catalogue with availability headroom.
    let inner = MilpPartitioner::default();
    let search = ShapeSearch::new(&types, &[2, 2], &inner, BnbLimits::default()).unwrap();
    let out = search.optimize(ShapeObjective::Deadline(deadline)).unwrap();
    assert!(out.point.latency <= deadline + 1e-9);
    assert!(
        out.point.cost < fixed_best - 1e-6,
        "shape search (${}) must beat the fixed-testbed heuristic (${fixed_best})",
        out.point.cost
    );
    // The win comes from the quantum boundary: the hourly instance stays
    // inside one billed hour instead of spilling into a second.
    assert!(out.point.cost <= 1.30 + 1e-9, "expected the $1.30 composition: {:?}", out.point);
}

#[test]
fn spot_composition_builds_and_executes() {
    // [catalogue] spot rentals: discounted rates + preemption hazards flow
    // from the TOML config through the session into the executor.
    let toml = r#"
        [workload]
        n_tasks = 4
        seed = 7
        accuracy = 0.05
        step_choices = [64]

        [cluster]
        kind = "small"
        seed = 42

        [catalogue]
        counts = [1, 2, 1]
        spot = true
    "#;
    let cfg = ExperimentConfig::parse(toml).unwrap();
    assert_eq!(cfg.cluster.counts, Some(vec![1, 2, 1]));
    assert!(cfg.cluster.spot);
    let session = SessionBuilder::from_config(cfg).partitioner("heuristic").build().unwrap();
    let specs = session.experiment().cluster.specs();
    assert_eq!(specs.len(), 4);
    // The FPGA offer has no spot market; the GPU and CPU ones do.
    assert_eq!(specs[0].preemptible, None);
    assert!(specs[1].preemptible.is_some() && specs[2].preemptible.is_some());
    assert!(specs[1].rate_per_hour < Catalogue::small().offer(1).spec.rate_per_hour);
    assert_eq!(
        session.composition(),
        vec![
            ("virtex6".to_string(), 1),
            ("gk104".to_string(), 2),
            ("xeon-e5-2660".to_string(), 1)
        ]
    );
    // The run completes; the always-on-demand FPGA lane keeps it alive
    // whatever the spot lanes do. With the mild default hazard the spot
    // lanes almost never preempt at these virtual timescales — and when
    // they do, re-homed retries still deliver prices.
    let ev = session.evaluate(None).unwrap();
    let priced = ev.execution.prices.iter().flatten().count();
    assert!(priced >= 1, "the run must price work");
    if ev.execution.preemptions == 0 {
        assert_eq!(ev.execution.failures, 0);
        assert_eq!(priced, 4);
    }
}
