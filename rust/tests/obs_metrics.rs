//! End-to-end telemetry-plane coverage: one `evaluate` populates solver,
//! executor, and cache metrics in the session registry; background runs and
//! the final report agree with the single event-loop tally; the Chrome-trace
//! export is well-formed; and `[obs] enabled = false` leaves every computed
//! result bit-identical while the always-on tallies (cache, run counters)
//! keep serving `ping`.
//!
//! Trace state and the B&B metrics are process-global, so every test here
//! takes one lock — a disabled session build flips the global trace flag,
//! which must not race the trace-export test.

use std::sync::{Mutex, MutexGuard};

use cloudshapes::api::{SessionBuilder, TradeoffSession};
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::executor::{execute_with, ExecutorConfig, RebalanceConfig};
use cloudshapes::coordinator::{HeuristicPartitioner, ModelSet};
use cloudshapes::obs::{self, trace, MetricsRegistry};
use cloudshapes::platforms::spec::small_cluster;
use cloudshapes::platforms::{Cluster, SimConfig};
use cloudshapes::util::json::Json;
use cloudshapes::workload::{generate, GeneratorConfig};

fn guard() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn quick_session(obs_enabled: bool) -> TradeoffSession {
    let mut cfg = ExperimentConfig::quick();
    cfg.obs.enabled = obs_enabled;
    SessionBuilder::from_config(cfg).partitioner("heuristic").build().unwrap()
}

#[test]
fn evaluate_populates_solver_executor_and_cache_metrics() {
    let _g = guard();
    let s = quick_session(true);
    let ev = s.evaluate_with(Some("heuristic"), None).unwrap();
    let m = s.metrics(None);

    // Solve latency lands as a per-strategy histogram.
    let solve = m.get("solve_latency_secs").expect("solve histogram");
    assert_eq!(solve.get("type").and_then(Json::as_str), Some("histogram"));
    let per_strategy = solve.get("values").unwrap().get("strategy=heuristic").unwrap();
    assert_eq!(per_strategy.get("count").unwrap().as_u64(), Some(1));

    // Per-platform chunk latency: one observation per completed chunk.
    let chunk = m.get("exec_chunk_latency_secs").expect("chunk histogram");
    let values = chunk.get("values").unwrap().as_obj().unwrap();
    assert!(!values.is_empty() && values.keys().all(|k| k.starts_with("platform=")));
    let observed: u64 =
        values.values().map(|v| v.get("count").unwrap().as_u64().unwrap()).sum();
    assert_eq!(observed, ev.execution.chunks as u64);

    // Predicted-vs-measured error is labelled by platform AND task.
    let err = m.get("exec_model_error_rel").expect("model error histogram");
    let labels = err.get("values").unwrap().as_obj().unwrap();
    assert!(!labels.is_empty());
    assert!(labels.keys().all(|k| k.contains("platform=") && k.contains("task=")));

    // The registry counters ARE the report's counters — one tally, two
    // views, so they can never disagree.
    let reg = s.metrics_registry();
    assert_eq!(reg.counter_value("exec_retries_total", ""), ev.execution.retries as u64);
    assert_eq!(
        reg.counter_value("exec_migrations_total", ""),
        ev.execution.migrations as u64
    );
    assert_eq!(
        reg.counter_value("exec_preemptions_total", ""),
        ev.execution.preemptions as u64
    );
    assert_eq!(reg.counter_value("exec_failures_total", ""), ev.execution.failures as u64);
    assert_eq!(reg.counter_value("exec_runs_total", ""), 1);
    assert_eq!(reg.gauge_value("exec_chunks_outstanding", ""), Some(0.0));

    // One makespan observation for the run.
    let makespan = m.get("exec_makespan_secs").unwrap().get("values").unwrap();
    assert_eq!(makespan.get("").unwrap().get("count").unwrap().as_u64(), Some(1));

    // Cache stats and registry read the same counters.
    let stats = s.cache_stats();
    assert_eq!(reg.counter_value("cache_hits_total", ""), stats.hits);
    assert_eq!(reg.counter_value("cache_misses_total", ""), stats.misses);
    assert_eq!(stats.misses, 1);

    // A name filter narrows the snapshot.
    let filtered = s.metrics(Some("exec_"));
    let names = filtered.as_obj().unwrap();
    assert!(!names.is_empty() && names.keys().all(|k| k.contains("exec_")));
}

#[test]
fn milp_solve_merges_global_bnb_metrics_into_the_snapshot() {
    let _g = guard();
    let mut cfg = ExperimentConfig::quick();
    cfg.milp.time_limit_secs = 2.0;
    let s = SessionBuilder::from_config(cfg).partitioner("milp").build().unwrap();
    s.partition(None).unwrap();
    // B&B records into the process-global registry; the session snapshot
    // overlays it, so both appear in one `metrics` response.
    let m = s.metrics(None);
    let nodes = m.get("bnb_nodes_total").expect("global B&B counter in merged snapshot");
    assert!(nodes.get("values").unwrap().get("").unwrap().as_u64().unwrap() >= 1);
    let solves = m.get("bnb_solve_secs").expect("global B&B histogram");
    let solve_count =
        solves.get("values").unwrap().get("").unwrap().get("count").unwrap().as_u64();
    assert!(solve_count.unwrap() >= 1);
    assert!(m.get("solve_latency_secs").is_some(), "session metrics ride along");
}

#[test]
fn background_run_status_matches_the_registry_tally() {
    use cloudshapes::api::session::RunState;
    let _g = guard();
    let s = quick_session(true);
    let id = s.start_run(Some("heuristic"), None).unwrap();
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let status = loop {
        let st = s.run_status(id).expect("run tracked");
        match &st.state {
            RunState::Running => {
                assert!(std::time::Instant::now() < deadline, "run never finished");
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            RunState::Done => break st,
            RunState::Failed(msg) => panic!("run failed: {msg}"),
        }
    };
    // The status view and the metrics registry derive from the same event
    // stream — the executor's one tally.
    let reg = s.metrics_registry();
    assert_eq!(status.chunks_done, status.chunks_total);
    assert_eq!(reg.counter_value("exec_runs_total", ""), 1);
    assert_eq!(reg.counter_value("exec_retries_total", ""), status.retries as u64);
    assert_eq!(reg.counter_value("exec_failures_total", ""), status.failures as u64);
    assert_eq!(reg.gauge_value("exec_chunks_outstanding", ""), Some(0.0));
    let m = s.metrics(Some("exec_chunk_latency_secs"));
    let observed: u64 = m
        .get("exec_chunk_latency_secs")
        .unwrap()
        .get("values")
        .unwrap()
        .as_obj()
        .unwrap()
        .values()
        .map(|v| v.get("count").unwrap().as_u64().unwrap())
        .sum();
    assert_eq!(observed, status.chunks_done as u64);
}

#[test]
fn trace_export_is_wellformed_chrome_json() {
    let _g = guard();
    trace::set_enabled(true);
    trace::clear();
    let s = quick_session(true);
    s.partition(None).unwrap();
    let text = trace::chrome_trace().to_string_pretty();
    let parsed = Json::parse(&text).expect("chrome trace is valid JSON");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(
        events
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("solve")),
        "solve span exported"
    );
    for e in events {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(e.get("cat").and_then(Json::as_str), Some("cloudshapes"));
        assert!(e.get("ts").unwrap().as_f64().is_some());
        assert!(e.get("dur").unwrap().as_f64().is_some());
        assert!(e.get("tid").unwrap().as_u64().is_some());
        assert!(e.get("args").unwrap().get("id").unwrap().as_u64().is_some());
    }
}

#[test]
fn disabled_obs_is_bit_identical_and_keeps_ping_tallies() {
    let _g = guard();
    let on = quick_session(true);
    let off = quick_session(false);

    // Identical configs (modulo the obs flag) must partition identically —
    // the hooks only read values the engine already computes.
    let p_on = on.partition(None).unwrap();
    let p_off = off.partition(None).unwrap();
    assert_eq!(p_on.predicted_latency_s.to_bits(), p_off.predicted_latency_s.to_bits());
    assert_eq!(p_on.predicted_cost.to_bits(), p_off.predicted_cost.to_bits());
    let m = on.models();
    for i in 0..m.mu {
        for j in 0..m.tau {
            assert_eq!(
                p_on.alloc.get(i, j).to_bits(),
                p_off.alloc.get(i, j).to_bits(),
                "allocation differs at ({i},{j})"
            );
        }
    }

    // The disabled registry records no name-addressed telemetry...
    assert!(off.metrics(None).get("solve_latency_secs").is_none());
    // ...but the handle-backed tallies `ping` reads still count.
    assert_eq!(off.cache_stats().misses, 1);
    assert_eq!(off.metrics_registry().counter_value("cache_misses_total", ""), 1);

    // Restore the global trace flag for the rest of the suite: the
    // disabled session's build turned it off process-wide.
    trace::set_enabled(true);

    // Executor path, noise-free simulator: hooks-on vs hooks-off reports
    // are bit-identical (rebalance off keeps the schedule deterministic).
    let specs = small_cluster();
    let sim = SimConfig::exact();
    let workload = generate(&GeneratorConfig::small(8, 0.02, 7));
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let cfg = ExecutorConfig {
        chunk_sims: 1 << 15,
        rebalance: RebalanceConfig { enabled: false, ..Default::default() },
        ..Default::default()
    };
    let bare_cluster = Cluster::simulated(&specs, &sim, 42).unwrap();
    let bare =
        execute_with(&bare_cluster, &workload, &alloc, &cfg, Some(&models), &mut |_| {})
            .unwrap();
    let reg = MetricsRegistry::default();
    let hooked_cluster = Cluster::simulated(&specs, &sim, 42).unwrap();
    let hooked =
        execute_with(&hooked_cluster, &workload, &alloc, &cfg, Some(&models), &mut |ev| {
            obs::record_exec_event(&reg, Some(&models), ev);
        })
        .unwrap();
    assert_eq!(bare.makespan_secs.to_bits(), hooked.makespan_secs.to_bits());
    assert_eq!(bare.cost.to_bits(), hooked.cost.to_bits());
    assert_eq!(
        (bare.chunks, bare.retries, bare.migrations, bare.preemptions, bare.failures),
        (hooked.chunks, hooked.retries, hooked.migrations, hooked.preemptions, hooked.failures)
    );
    assert_eq!(reg.counter_value("exec_runs_total", ""), 1);
}
