//! Oracle-backed validation of the exotic payoff families (ISSUE 10):
//!
//! - **American (LSMC)** against a Cox-Ross-Rubinstein binomial tree — the
//!   estimate must carry a strictly positive early-exercise premium over
//!   the European put closed form, yet never beat the (true) tree price;
//! - **Basket** against the geometric-basket closed form (a strict lower
//!   bound via AM-GM) and the Lévy moment-matched lognormal approximation;
//! - **Heston** in the degenerate `ξ = 0, v₀ = θ` limit against a
//!   test-local constant-vol GBM that replays the *same* Threefry stream —
//!   agreement to 1e-12 relative, independent of sampling noise — plus the
//!   Black-Scholes closed form at `√θ` vol within Monte Carlo error.
//!
//! Every test pins its seeds, so failures reproduce deterministically.

use cloudshapes::pricing::{blackscholes, combine, mc};
use cloudshapes::util::rng::threefry_normal;
use cloudshapes::workload::option::{OptionTask, Payoff};

fn american() -> OptionTask {
    OptionTask {
        id: 31,
        payoff: Payoff::American,
        spot: 100.0,
        strike: 110.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        steps: 32,
        ..OptionTask::default()
    }
}

fn basket() -> OptionTask {
    OptionTask {
        id: 33,
        payoff: Payoff::Basket,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.25,
        maturity: 1.0,
        steps: 16,
        assets: 4,
        correlation: 0.5,
        ..OptionTask::default()
    }
}

fn heston() -> OptionTask {
    OptionTask {
        id: 35,
        payoff: Payoff::Heston,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        maturity: 1.0,
        steps: 64,
        kappa: 1.5,
        theta: 0.04,
        xi: 0.5,
        v0: 0.04,
        correlation: -0.7,
        ..OptionTask::default()
    }
}

// ───────────────────────────── American / LSMC ──────────────────────────

#[test]
fn lsmc_american_put_sits_between_european_and_binomial() {
    let t = american();
    let est = combine(&mc::simulate(&t, 42, 0, 1 << 16), t.discount());
    let eur = blackscholes::put(t.spot, t.strike, t.rate, t.sigma, t.maturity);
    let crr =
        blackscholes::american_put_binomial(t.spot, t.strike, t.rate, t.sigma, t.maturity, 2000);
    // Early exercise must be worth something...
    assert!(
        est.price > eur + 3.0 * est.std_error,
        "no early-exercise premium: lsmc {} ± {} vs european {eur}",
        est.price,
        est.std_error
    );
    // ...but a (suboptimal) regression policy priced out-of-sample cannot
    // beat the true price.
    assert!(
        est.price <= crr + 3.0 * est.std_error,
        "lsmc {} ± {} above the binomial oracle {crr}",
        est.price,
        est.std_error
    );
    // And it should land near the oracle, not merely below it (32 exercise
    // dates vs the tree's continuous-exercise limit cost a little).
    assert!(
        (est.price - crr).abs() < 3.0 * est.std_error + 0.08 * crr,
        "lsmc {} ± {} far from binomial {crr}",
        est.price,
        est.std_error
    );
}

#[test]
fn lsmc_tracks_the_binomial_oracle_across_moneyness() {
    // Deep ITM, ATM, OTM: the premium structure must follow the tree.
    for (strike, id) in [(90.0, 41u64), (100.0, 42), (120.0, 43)] {
        let mut t = american();
        t.id = id;
        t.strike = strike;
        let est = combine(&mc::simulate(&t, 7, 0, 1 << 16), t.discount());
        let crr = blackscholes::american_put_binomial(
            t.spot, t.strike, t.rate, t.sigma, t.maturity, 2000,
        );
        assert!(
            (est.price - crr).abs() < 3.0 * est.std_error + 0.08 * crr.max(0.5),
            "K={strike}: lsmc {} ± {} vs binomial {crr}",
            est.price,
            est.std_error
        );
    }
}

#[test]
fn lsmc_premium_grows_with_more_exercise_dates() {
    // More exercise opportunities can only add value (up to MC noise): the
    // 64-date estimate must not fall materially below the 8-date one.
    let mut coarse = american();
    coarse.steps = 8;
    let mut fine = american();
    fine.steps = 64;
    let lo = combine(&mc::simulate(&coarse, 11, 0, 1 << 16), coarse.discount());
    let hi = combine(&mc::simulate(&fine, 11, 0, 1 << 16), fine.discount());
    assert!(
        hi.price > lo.price - 3.0 * (lo.std_error + hi.std_error),
        "64 dates {} ± {} below 8 dates {} ± {}",
        hi.price,
        hi.std_error,
        lo.price,
        lo.std_error
    );
}

// ──────────────────────────────── Basket ────────────────────────────────

#[test]
fn basket_dominates_its_geometric_lower_bound() {
    // AM >= GM pathwise, so the arithmetic-basket call dominates the
    // geometric-basket closed form at every correlation.
    for (rho, id) in [(0.1, 51u64), (0.5, 52), (0.8, 53)] {
        let mut t = basket();
        t.id = id;
        t.correlation = rho;
        let est = combine(&mc::simulate(&t, 17, 0, 1 << 16), t.discount());
        let geo = blackscholes::geometric_basket_call(
            t.spot,
            t.strike,
            t.rate,
            t.sigma,
            t.maturity,
            t.assets,
            rho,
        );
        assert!(
            est.price > geo - 3.0 * est.std_error,
            "rho={rho}: mc {} ± {} below geometric bound {geo}",
            est.price,
            est.std_error
        );
    }
}

#[test]
fn basket_matches_the_moment_matched_oracle() {
    // The Lévy lognormal approximation is good to a few tenths of a percent
    // at these vols — an independent numerical oracle for the level, not
    // just the ordering.
    for (rho, d, id) in [(0.5, 4u32, 61u64), (0.3, 8, 62), (0.8, 2, 63)] {
        let mut t = basket();
        t.id = id;
        t.assets = d;
        t.correlation = rho;
        let est = combine(&mc::simulate(&t, 29, 0, 1 << 16), t.discount());
        let mm = blackscholes::basket_call_moment_matched(
            t.spot, t.strike, t.rate, t.sigma, t.maturity, d, rho,
        );
        assert!(
            (est.price - mm).abs() < 4.0 * est.std_error + 0.015 * mm,
            "d={d} rho={rho}: mc {} ± {} vs moment-matched {mm}",
            est.price,
            est.std_error
        );
    }
}

// ──────────────────────────────── Heston ────────────────────────────────

/// Constant-vol GBM on the Heston kernel's `z_s` stream, replicating its
/// f32 arithmetic term for term. At `ξ = 0, v₀ = θ` the Heston variance
/// recursion is *exactly* constant (the κ(θ−v⁺)dt increment is a product
/// with an exact zero), so the kernel must reproduce this loop to the last
/// bit of its accumulators.
fn replay_degenerate_gbm(task: &OptionTask, seed: u32, offset: u64, n: u32) -> (f64, f64) {
    assert_eq!(task.xi, 0.0);
    assert_eq!(task.v0, task.theta);
    let k0 = task.id as u32;
    let k1 = seed;
    let steps = task.steps;
    let (s0, k, r, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.maturity as f32,
    );
    let v0 = task.v0 as f32;
    let dt = t / steps as f32;
    let sq = (v0 * dt).sqrt();
    let mut sum = 0.0f64;
    let mut sum_sq = 0.0f64;
    for p in 0..n {
        let g = offset.wrapping_add(p as u64);
        let (c0, hi) = (g as u32, ((g >> 32) as u32) << mc::STEP_BITS);
        let mut log_s = s0.ln();
        for step in 0..steps {
            // Sub-draw 2·step is the kernel's z_s; 2·step+1 (the variance
            // shock) is dead weight at ξ = 0 and never touches the price.
            let z_s = threefry_normal(k0, k1, c0, hi | (2 * step));
            log_s += (r - 0.5 * v0) * dt + sq * z_s;
        }
        let payoff = ((log_s.exp() - k).max(0.0)) as f64;
        sum += payoff;
        sum_sq += payoff * payoff;
    }
    (sum, sum_sq)
}

#[test]
fn heston_degenerate_limit_replays_gbm_to_1e12() {
    let mut t = heston();
    t.xi = 0.0;
    t.v0 = t.theta;
    let stats = mc::simulate(&t, 42, 0, 1 << 14);
    let (sum, sum_sq) = replay_degenerate_gbm(&t, 42, 0, 1 << 14);
    let rel = (stats.sum - sum).abs() / sum.abs().max(1.0);
    assert!(rel <= 1e-12, "sum: heston {} vs gbm replay {} (rel {rel})", stats.sum, sum);
    let rel2 = (stats.sum_sq - sum_sq).abs() / sum_sq.abs().max(1.0);
    assert!(rel2 <= 1e-12, "sum_sq: heston {} vs gbm replay {}", stats.sum_sq, sum_sq);
    assert_eq!(stats.n, 1 << 14);

    // Chunked offsets replay identically too (the counter bijection, not
    // just the zero-offset stream).
    let stats = mc::simulate(&t, 7, 1 << 10, 512);
    let (sum, _) = replay_degenerate_gbm(&t, 7, 1 << 10, 512);
    assert!((stats.sum - sum).abs() / sum.abs().max(1.0) <= 1e-12);
}

#[test]
fn heston_degenerate_limit_matches_black_scholes() {
    let mut t = heston();
    t.xi = 0.0;
    t.v0 = t.theta;
    let est = combine(&mc::simulate(&t, 13, 0, 1 << 16), t.discount());
    let bs = blackscholes::call(t.spot, t.strike, t.rate, t.theta.sqrt(), t.maturity);
    assert!(
        (est.price - bs).abs() < 3.0 * est.std_error + 0.03,
        "mc {} ± {} vs bs(√θ) {bs}",
        est.price,
        est.std_error
    );
}

#[test]
fn heston_mean_reversion_pulls_prices_between_the_vol_extremes() {
    // v₀ far from θ: the effective vol over [0, T] sits between √v₀ and
    // √θ, so the price must lie between the two Black-Scholes extremes
    // (with an ξ cushion — vol-of-vol convexity shifts OTM prices).
    let mut t = heston();
    t.v0 = 0.09; // starts at 30% vol, reverts toward 20%
    t.xi = 0.2;
    let est = combine(&mc::simulate(&t, 19, 0, 1 << 16), t.discount());
    let hi = blackscholes::call(t.spot, t.strike, t.rate, 0.3, t.maturity);
    let lo = blackscholes::call(t.spot, t.strike, t.rate, 0.2, t.maturity);
    assert!(
        est.price > lo - 4.0 * est.std_error && est.price < hi + 4.0 * est.std_error,
        "mc {} ± {} outside BS envelope [{lo}, {hi}]",
        est.price,
        est.std_error
    );
}
