//! The structure-aware partitioning B&B (`coordinator::partitioner::milp`)
//! vs the generic MILP solver (`milp::branch_bound`) fed the FULL Eq. 4
//! formulation (explicit binary B with A ≤ B linking rows, integer D):
//! on small instances both must find the same optimal makespan.

use cloudshapes::coordinator::partitioner::{MilpConfig, MilpPartitioner};
use cloudshapes::coordinator::ModelSet;
use cloudshapes::milp::{self, BnbLimits, Cmp, MilpStatus, Problem};
use cloudshapes::models::{CostModel, LatencyModel};
use cloudshapes::util::rng::Rng;

/// Build the *full* Eq. 4 problem: A (cont), B (bin, A<=B), D (int), F_L.
fn full_formulation(models: &ModelSet, budget: Option<f64>) -> Problem {
    let (mu, tau) = (models.mu, models.tau);
    let mut p = Problem::new();
    let a: Vec<_> = (0..mu * tau).map(|k| p.cont(&format!("a{k}"), 0.0, 1.0)).collect();
    let b: Vec<_> = (0..mu * tau).map(|k| p.bin(&format!("b{k}"))).collect();
    let f_l = p.cont("F_L", 0.0, f64::INFINITY);
    let d: Vec<_> = (0..mu).map(|i| p.int(&format!("d{i}"), 0.0, 1e6)).collect();

    for j in 0..tau {
        p.constrain((0..mu).map(|i| (a[i * tau + j], 1.0)).collect(), Cmp::Eq, 1.0);
    }
    for k in 0..mu * tau {
        // A_ij - B_ij <= 0.
        p.constrain(vec![(a[k], 1.0), (b[k], -1.0)], Cmp::Le, 0.0);
    }
    for i in 0..mu {
        let mut lat: Vec<_> = (0..tau)
            .flat_map(|j| {
                let k = i * tau + j;
                [(a[k], models.work_secs(i, j)), (b[k], models.setup_secs(i, j))]
            })
            .collect();
        let mut quantum = lat.clone();
        lat.push((f_l, -1.0));
        p.constrain(lat, Cmp::Le, 0.0);
        quantum.push((d[i], -models.cost[i].quantum_secs));
        p.constrain(quantum, Cmp::Le, 0.0);
    }
    if let Some(c_k) = budget {
        p.constrain(
            (0..mu).map(|i| (d[i], models.cost[i].rate_per_quantum())).collect(),
            Cmp::Le,
            c_k,
        );
    }
    p.minimize(vec![(f_l, 1.0)]);
    p
}

fn random_models(rng: &mut Rng, mu: usize, tau: usize) -> ModelSet {
    let quanta = [60.0, 600.0, 3600.0];
    let mut latency = Vec::new();
    for _ in 0..mu {
        for _ in 0..tau {
            let beta = (rng.range_f64(1e-6_f64.ln(), 1e-4_f64.ln())).exp();
            let gamma = rng.range_f64(0.5, 30.0);
            latency.push(LatencyModel::new(beta, gamma));
        }
    }
    let cost: Vec<CostModel> = (0..mu)
        .map(|_| CostModel::new(*rng.choose(&quanta), rng.range_f64(0.1, 1.0)).unwrap())
        .collect();
    let n: Vec<u64> = (0..tau).map(|_| rng.range_u64(100_000, 5_000_000)).collect();
    ModelSet::new(latency, cost, n, (0..mu).map(|i| format!("p{i}")).collect())
}

fn tight_cfg() -> MilpConfig {
    MilpConfig { max_nodes: 20_000, rel_gap: 1e-6, time_limit_secs: 30.0, workers: 1 }
}

#[test]
fn unconstrained_matches_generic_solver() {
    let mut rng = Rng::new(0xE9_4);
    for trial in 0..6 {
        let models = random_models(&mut rng, 2, 3);
        let spec = MilpPartitioner::new(tight_cfg()).solve(&models, None).unwrap();
        let generic = milp::solve_milp(
            &full_formulation(&models, None),
            &BnbLimits { max_nodes: 200_000, rel_gap: 1e-6, time_limit_secs: 60.0, workers: 1 },
        );
        assert_eq!(generic.status, MilpStatus::Optimal, "trial {trial}");
        let rel = (spec.makespan - generic.obj).abs() / generic.obj;
        assert!(
            rel < 1e-3,
            "trial {trial}: specialized {} vs generic {} (rel {rel})",
            spec.makespan,
            generic.obj
        );
    }
}

#[test]
fn budgeted_matches_generic_solver() {
    let mut rng = Rng::new(0xB4D6E7);
    let mut checked = 0;
    for trial in 0..8 {
        let models = random_models(&mut rng, 2, 2);
        // Budget halfway between C_L and the unconstrained cost.
        let un = MilpPartitioner::new(tight_cfg()).solve(&models, None).unwrap();
        let (c_l, _) =
            cloudshapes::coordinator::partitioner::lower_cost_bound(&models);
        if un.cost <= c_l + 1e-9 {
            continue; // degenerate: no trade-off to constrain
        }
        let budget = (c_l + un.cost) / 2.0;
        let spec = match MilpPartitioner::new(tight_cfg()).solve(&models, Some(budget)) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let generic = milp::solve_milp(
            &full_formulation(&models, Some(budget)),
            &BnbLimits { max_nodes: 200_000, rel_gap: 1e-6, time_limit_secs: 60.0, workers: 1 },
        );
        if generic.status != MilpStatus::Optimal {
            continue; // generic solver budget exceeded; skip, don't fail
        }
        checked += 1;
        // The specialized solver is exact up to its gap; require agreement
        // within 1% (both report true-ceiling-semantics makespans).
        let rel = (spec.makespan - generic.obj) / generic.obj;
        assert!(
            rel.abs() < 0.01 || spec.makespan <= generic.obj,
            "trial {trial}: specialized {} vs generic {} (budget {budget})",
            spec.makespan,
            generic.obj
        );
    }
    assert!(checked >= 3, "too few comparable trials ({checked})");
}

#[test]
fn generic_formulation_is_feasible_for_specialized_solution() {
    // Cross-check the formulations agree on semantics: embed the
    // specialized solver's allocation into the full Eq. 4 variable space
    // and verify it satisfies every constraint.
    let mut rng = Rng::new(77);
    let models = random_models(&mut rng, 3, 4);
    let out = MilpPartitioner::new(tight_cfg()).solve(&models, None).unwrap();
    let p = full_formulation(&models, None);
    let (mu, tau) = (models.mu, models.tau);
    let mut x = vec![0.0; p.n_vars()];
    for i in 0..mu {
        for j in 0..tau {
            let a = out.alloc.get(i, j);
            x[i * tau + j] = a;
            x[mu * tau + i * tau + j] = if a > 1e-9 { 1.0 } else { 0.0 };
        }
    }
    x[2 * mu * tau] = out.makespan; // F_L
    for i in 0..mu {
        let lat = models.platform_latency(&out.alloc, i);
        x[2 * mu * tau + 1 + i] = models.cost[i].quanta(lat) as f64;
    }
    assert!(p.is_feasible(&x, 1e-6), "specialized solution infeasible in Eq. 4");
    assert!((p.objective_value(&x) - out.makespan).abs() < 1e-9);
}
