//! Greeks validation (ISSUE 10):
//!
//! - **No-regression**: the Greek accumulators were appended *after* each
//!   path's price accumulation, so `sum` / `sum_sq` / `n` of the legacy
//!   families (European, Asian, Barrier) must be **bit-identical** to
//!   price-only replicas of the pre-Greeks kernels (reimplemented locally,
//!   term for term).
//! - **Pathwise estimators** (European, Asian, Basket, Heston) against
//!   central finite differences under common random numbers — and, for the
//!   European call, against the Black-Scholes closed forms.
//! - **Likelihood-ratio estimators** (Barrier, American — the knock-out
//!   indicator and exercise boundary kill the pathwise derivative) against
//!   the same CRN finite differences at looser, variance-appropriate
//!   tolerances.
//!
//! Seeds are pinned throughout.

use cloudshapes::pricing::mc::{self, GreekEstimate};
use cloudshapes::pricing::{blackscholes, combine};
use cloudshapes::util::rng::threefry_normal;
use cloudshapes::workload::option::{OptionTask, Payoff};

fn assert_close(got: f64, want: f64, rel: f64, abs: f64, what: &str) {
    assert!(
        (got - want).abs() <= rel * want.abs() + abs,
        "{what}: estimator {got} vs oracle {want} (rel {rel}, abs {abs})"
    );
}

fn base(payoff: Payoff) -> OptionTask {
    OptionTask {
        id: 23,
        payoff,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        barrier: 140.0,
        steps: if payoff == Payoff::European { 1 } else { 64 },
        assets: if payoff == Payoff::Basket { 4 } else { 1 },
        correlation: match payoff {
            Payoff::Basket => 0.5,
            Payoff::Heston => -0.7,
            _ => 0.0,
        },
        ..OptionTask::default()
    }
}

/// Central finite differences of the discounted price in spot and vol,
/// re-simulated under the *same* seed (common random numbers) so the
/// difference variance collapses. The vol bump hits `sigma` for the GBM
/// families and the initial vol `√v₀` for Heston.
fn fd_greeks(task: &OptionTask, seed: u32, n: u32, h_s: f64, h_v: f64) -> (f64, f64) {
    let price = |t: &OptionTask| combine(&mc::simulate(t, seed, 0, n), t.discount()).price;
    let mut su = task.clone();
    su.spot += h_s;
    let mut sd = task.clone();
    sd.spot -= h_s;
    let delta = (price(&su) - price(&sd)) / (2.0 * h_s);
    let mut vu = task.clone();
    let mut vd = task.clone();
    if task.payoff == Payoff::Heston {
        vu.v0 = (task.v0.sqrt() + h_v).powi(2);
        vd.v0 = (task.v0.sqrt() - h_v).powi(2);
    } else {
        vu.sigma += h_v;
        vd.sigma -= h_v;
    }
    let vega = (price(&vu) - price(&vd)) / (2.0 * h_v);
    (delta, vega)
}

fn greeks(task: &OptionTask, seed: u32, n: u32) -> GreekEstimate {
    mc::combine_greeks(&mc::simulate(task, seed, 0, n), task.discount())
}

// ─────────────────── sum/sum_sq bit-identity (no regression) ─────────────

/// Price-only European kernel exactly as it stood before the Greek
/// accumulators landed.
fn european_price_only(task: &OptionTask, seed: u32, offset: u64, n: u32) -> (f64, f64) {
    let (k0, k1) = (task.id as u32, seed);
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let drift = (r - 0.5 * sigma * sigma) * t;
    let vol = sigma * t.sqrt();
    let (mut sum, mut sum_sq) = (0.0f64, 0.0f64);
    for p in 0..n {
        let g = offset.wrapping_add(p as u64);
        let (c0, hi) = (g as u32, ((g >> 32) as u32) << mc::STEP_BITS);
        let z = threefry_normal(k0, k1, c0, hi);
        let st = s0 * (drift + vol * z).exp();
        let payoff = (st - k).max(0.0) as f64;
        sum += payoff;
        sum_sq += payoff * payoff;
    }
    (sum, sum_sq)
}

/// Price-only Asian kernel (pre-Greeks).
fn asian_price_only(task: &OptionTask, seed: u32, offset: u64, n: u32) -> (f64, f64) {
    let (k0, k1) = (task.id as u32, seed);
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let steps = task.steps;
    let dt = t / steps as f32;
    let drift = (r - 0.5 * sigma * sigma) * dt;
    let vol = sigma * dt.sqrt();
    let (mut sum, mut sum_sq) = (0.0f64, 0.0f64);
    for p in 0..n {
        let g = offset.wrapping_add(p as u64);
        let (c0, hi) = (g as u32, ((g >> 32) as u32) << mc::STEP_BITS);
        let mut log_s = s0.ln();
        let mut acc = 0.0f32;
        for step in 0..steps {
            let z = threefry_normal(k0, k1, c0, hi | step);
            log_s += drift + vol * z;
            acc += log_s.exp();
        }
        let avg = acc / steps as f32;
        let payoff = (avg - k).max(0.0) as f64;
        sum += payoff;
        sum_sq += payoff * payoff;
    }
    (sum, sum_sq)
}

/// Price-only Barrier kernel (pre-Greeks).
fn barrier_price_only(task: &OptionTask, seed: u32, offset: u64, n: u32) -> (f64, f64) {
    let (k0, k1) = (task.id as u32, seed);
    let (s0, k, r, sigma, t) = (
        task.spot as f32,
        task.strike as f32,
        task.rate as f32,
        task.sigma as f32,
        task.maturity as f32,
    );
    let steps = task.steps;
    let barrier = task.barrier as f32;
    let dt = t / steps as f32;
    let drift = (r - 0.5 * sigma * sigma) * dt;
    let vol = sigma * dt.sqrt();
    let (mut sum, mut sum_sq) = (0.0f64, 0.0f64);
    for p in 0..n {
        let g = offset.wrapping_add(p as u64);
        let (c0, hi) = (g as u32, ((g >> 32) as u32) << mc::STEP_BITS);
        let mut log_s = s0.ln();
        let mut alive = s0 < barrier;
        for step in 0..steps {
            let z = threefry_normal(k0, k1, c0, hi | step);
            log_s += drift + vol * z;
            alive = alive && log_s.exp() < barrier;
        }
        let payoff = if alive { (log_s.exp() - k).max(0.0) as f64 } else { 0.0 };
        sum += payoff;
        sum_sq += payoff * payoff;
    }
    (sum, sum_sq)
}

#[test]
fn greek_accumulators_leave_price_sums_bit_identical() {
    // The pre-Greeks replicas and the live kernels must agree to the LAST
    // BIT — Greeks ride along, they never perturb the price stream.
    type Replica = fn(&OptionTask, u32, u64, u32) -> (f64, f64);
    let cases: [(Payoff, Replica); 3] = [
        (Payoff::European, european_price_only),
        (Payoff::Asian, asian_price_only),
        (Payoff::Barrier, barrier_price_only),
    ];
    for (payoff, replica) in cases {
        let t = base(payoff);
        for (seed, offset, n) in [(1u32, 0u64, 4096u32), (9, 1 << 9, 777), (5, 1u64 << 33, 512)] {
            let stats = mc::simulate(&t, seed, offset, n);
            let (sum, sum_sq) = replica(&t, seed, offset, n);
            assert_eq!(stats.sum, sum, "{payoff:?} seed {seed} offset {offset}: sum drifted");
            assert_eq!(stats.sum_sq, sum_sq, "{payoff:?} seed {seed}: sum_sq drifted");
            assert_eq!(stats.n, n as u64, "{payoff:?}: path count");
        }
    }
}

// ─────────────────────────── pathwise families ───────────────────────────

#[test]
fn european_pathwise_greeks_match_closed_form_and_fd() {
    let t = base(Payoff::European);
    let g = greeks(&t, 42, 1 << 17);
    let bs_delta = blackscholes::call_delta(t.spot, t.strike, t.rate, t.sigma, t.maturity);
    let bs_vega = blackscholes::call_vega(t.spot, t.strike, t.rate, t.sigma, t.maturity);
    assert_close(g.delta, bs_delta, 0.03, 0.01, "european delta vs closed form");
    assert_close(g.vega, bs_vega, 0.06, 0.2, "european vega vs closed form");
    let (fd_delta, fd_vega) = fd_greeks(&t, 42, 1 << 17, 1.0, 0.01);
    assert_close(g.delta, fd_delta, 0.05, 0.02, "european delta vs CRN FD");
    assert_close(g.vega, fd_vega, 0.10, 0.5, "european vega vs CRN FD");
}

#[test]
fn asian_pathwise_greeks_match_crn_finite_differences() {
    let t = base(Payoff::Asian);
    let g = greeks(&t, 7, 1 << 16);
    let (fd_delta, fd_vega) = fd_greeks(&t, 7, 1 << 16, 1.0, 0.01);
    // Sanity: an average-rate call has delta in (0, 1) and positive vega.
    assert!(g.delta > 0.0 && g.delta < 1.0, "asian delta {}", g.delta);
    assert!(g.vega > 0.0, "asian vega {}", g.vega);
    assert_close(g.delta, fd_delta, 0.10, 0.03, "asian delta vs CRN FD");
    assert_close(g.vega, fd_vega, 0.15, 1.0, "asian vega vs CRN FD");
}

#[test]
fn basket_pathwise_greeks_match_crn_finite_differences() {
    let t = base(Payoff::Basket);
    let g = greeks(&t, 11, 1 << 16);
    let (fd_delta, fd_vega) = fd_greeks(&t, 11, 1 << 16, 1.0, 0.01);
    assert!(g.delta > 0.0 && g.delta < 1.0, "basket delta {}", g.delta);
    assert!(g.vega > 0.0, "basket vega {}", g.vega);
    assert_close(g.delta, fd_delta, 0.10, 0.03, "basket delta vs CRN FD");
    assert_close(g.vega, fd_vega, 0.15, 1.5, "basket vega vs CRN FD");
}

#[test]
fn heston_pathwise_greeks_match_crn_finite_differences() {
    let t = base(Payoff::Heston);
    let g = greeks(&t, 13, 1 << 16);
    // Vega is taken wrt the initial vol √v₀ — bump √v₀ in the FD too.
    let (fd_delta, fd_vega) = fd_greeks(&t, 13, 1 << 16, 1.0, 0.01);
    assert!(g.delta > 0.0 && g.delta < 1.0, "heston delta {}", g.delta);
    assert_close(g.delta, fd_delta, 0.10, 0.03, "heston delta vs CRN FD");
    // The truncation subgradient and f32 chain-rule state cost accuracy:
    // looser than the GBM families, still unambiguous.
    assert_close(g.vega, fd_vega, 0.25, 2.0, "heston vega vs CRN FD");
}

#[test]
fn heston_degenerate_vega_matches_black_scholes() {
    // ξ = 0, v₀ = θ: Heston IS Black-Scholes at σ = √θ, and the pathwise
    // chain-rule vega must collapse to the European pathwise vega.
    let mut t = base(Payoff::Heston);
    t.xi = 0.0;
    t.v0 = t.theta;
    let g = greeks(&t, 17, 1 << 17);
    let sigma = t.theta.sqrt();
    let bs_delta = blackscholes::call_delta(t.spot, t.strike, t.rate, sigma, t.maturity);
    let bs_vega = blackscholes::call_vega(t.spot, t.strike, t.rate, sigma, t.maturity);
    assert_close(g.delta, bs_delta, 0.04, 0.01, "degenerate heston delta");
    assert_close(g.vega, bs_vega, 0.10, 0.5, "degenerate heston vega");
}

// ─────────────────────── likelihood-ratio families ───────────────────────

#[test]
fn barrier_lr_greeks_match_crn_finite_differences() {
    let t = base(Payoff::Barrier);
    let g = greeks(&t, 3, 1 << 17);
    let (fd_delta, fd_vega) = fd_greeks(&t, 3, 1 << 17, 1.0, 0.01);
    // LR estimators are unbiased but noisy; CRN FD of a discontinuous
    // payoff carries O(h) kink noise — meet in the middle with loose
    // tolerances that still pin sign and scale.
    assert_close(g.delta, fd_delta, 0.25, 0.08, "barrier LR delta vs CRN FD");
    assert_close(g.vega, fd_vega, 0.30, 4.0, "barrier LR vega vs CRN FD");
}

#[test]
fn american_lr_greeks_match_crn_finite_differences() {
    let t = OptionTask {
        id: 27,
        payoff: Payoff::American,
        spot: 100.0,
        strike: 110.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        steps: 32,
        ..OptionTask::default()
    };
    let g = greeks(&t, 5, 1 << 17);
    let (fd_delta, fd_vega) = fd_greeks(&t, 5, 1 << 17, 1.0, 0.01);
    // An ITM American put: delta decidedly negative, vega positive.
    assert!(g.delta < -0.2, "american put delta {}", g.delta);
    assert!(g.vega > 0.0, "american put vega {}", g.vega);
    assert_close(g.delta, fd_delta, 0.25, 0.10, "american LR delta vs CRN FD");
    assert_close(g.vega, fd_vega, 0.30, 5.0, "american LR vega vs CRN FD");
}

#[test]
fn greek_accumulators_merge_additively_across_chunks() {
    // Chunked execution must merge Greeks exactly like prices — for every
    // family, including the LR ones whose scores weight the payoff.
    for payoff in Payoff::ALL {
        let mut t = base(payoff);
        t.steps = if payoff == Payoff::European { 1 } else { 16 };
        let whole = mc::simulate(&t, 21, 0, 2048);
        let merged = mc::simulate(&t, 21, 0, 800).merge(&mc::simulate(&t, 21, 800, 1248));
        let tol = |x: f64| 1e-9 * x.abs().max(1.0);
        assert!(
            (whole.delta_sum - merged.delta_sum).abs() < tol(whole.delta_sum),
            "{payoff:?} delta_sum"
        );
        assert!(
            (whole.vega_sum - merged.vega_sum).abs() < tol(whole.vega_sum),
            "{payoff:?} vega_sum"
        );
        assert_eq!(whole.n, merged.n, "{payoff:?}");
    }
}
