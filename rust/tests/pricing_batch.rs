//! Differential suite for the batched Monte Carlo kernel: the scalar
//! pricer (`pricing::mc::simulate`) is the oracle and the batched kernel
//! (`pricing::batch`) must reproduce it **bit-for-bit** — same counter
//! bijection, same per-lane f32 rounding, same f64 merge order — across
//! every payoff family, ragged tails, offsets straddling `2^32` and
//! `steps` at the counter-layout boundary. The suite closes with the
//! executor-level check: chunked evaluation reports are unchanged (1e-9)
//! when the simulated cluster swaps the batched kernel in.

use cloudshapes::coordinator::executor::{execute, ExecutorConfig, RebalanceConfig};
use cloudshapes::coordinator::{HeuristicPartitioner, ModelSet};
use cloudshapes::platforms::spec::small_cluster;
use cloudshapes::platforms::{Cluster, SimConfig};
use cloudshapes::pricing::batch::{simulate_batch, simulate_lanes, KernelConfig, LANES};
use cloudshapes::pricing::mc::{simulate, STEP_BITS};
use cloudshapes::testing::golden_rng::{GOLDEN_RNG, GROUPS, Z_TOL};
use cloudshapes::workload::option::{OptionTask, Payoff};
use cloudshapes::workload::{generate, GeneratorConfig};

fn task(payoff: Payoff, steps: u32) -> OptionTask {
    OptionTask {
        id: 7,
        payoff,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        barrier: 140.0,
        steps,
        target_accuracy: 0.01,
        n_sims: 1 << 20,
        assets: if payoff == Payoff::Basket { 4 } else { 1 },
        correlation: match payoff {
            Payoff::Basket => 0.5,
            Payoff::Heston => -0.7,
            _ => 0.0,
        },
        ..OptionTask::default()
    }
}

fn families() -> [OptionTask; 6] {
    [
        task(Payoff::European, 1),
        task(Payoff::Asian, 16),
        task(Payoff::Barrier, 16),
        task(Payoff::American, 16),
        task(Payoff::Basket, 16),
        task(Payoff::Heston, 16),
    ]
}

#[test]
fn batched_is_bitwise_scalar_across_families_seeds_and_offsets() {
    for t in families() {
        for seed in [0u32, 1, 42, u32::MAX] {
            for offset in [0u64, 1, 1000, (1u64 << 31) + 5] {
                let a = simulate(&t, seed, offset, 4096);
                let b = simulate_batch(&t, seed, offset, 4096);
                assert_eq!(a, b, "{:?} seed {seed} offset {offset}", t.payoff);
            }
        }
    }
}

#[test]
fn ragged_tails_are_bitwise_scalar() {
    // Every residue class modulo the lane width, including n < LANES.
    for t in families() {
        for n in 1..=(2 * LANES as u32 + 1) {
            assert_eq!(
                simulate(&t, 3, 17, n),
                simulate_batch(&t, 3, 17, n),
                "{:?} n={n}",
                t.payoff
            );
        }
    }
}

#[test]
fn offsets_straddling_2_pow_32_are_bitwise_scalar() {
    // The block crosses the c0 wrap mid-lane: low lanes keep c1's high
    // bits at 0, high lanes carry the folded overflow — both must match
    // the scalar counter split exactly.
    for t in families() {
        for base in [
            (1u64 << 32) - 3,
            (1u64 << 32) - LANES as u64,
            (1u64 << 32) + 1,
            (1u64 << 33) - 1,
        ] {
            let a = simulate(&t, 9, base, 2 * LANES as u32 + 3);
            let b = simulate_batch(&t, 9, base, 2 * LANES as u32 + 3);
            assert_eq!(a, b, "{:?} base={base}", t.payoff);
        }
    }
}

#[test]
fn steps_at_the_counter_layout_boundary_are_bitwise_scalar() {
    // The largest step count the layout admits: the step word fills all
    // STEP_BITS low bits, adjacent to the folded-offset high bits. Few
    // paths — the point is the counter arithmetic, not the statistics.
    let boundary = (1u32 << STEP_BITS) - 1;
    for payoff in [Payoff::Asian, Payoff::Barrier] {
        let t = task(payoff, boundary);
        assert_eq!(
            simulate(&t, 5, (1u64 << 32) + 2, 3),
            simulate_batch(&t, 5, (1u64 << 32) + 2, 3),
            "{payoff:?}"
        );
    }
    // Multi-draw families fill the budget at steps·draws_per_step words:
    // basket (4 assets) tops out at 2^18−1 steps, Heston at 2^19−1.
    let basket = task(Payoff::Basket, (1u32 << (STEP_BITS - 2)) - 1);
    assert_eq!(
        simulate(&basket, 5, (1u64 << 32) + 2, 2),
        simulate_batch(&basket, 5, (1u64 << 32) + 2, 2)
    );
    let heston = task(Payoff::Heston, (1u32 << (STEP_BITS - 1)) - 1);
    assert_eq!(
        simulate(&heston, 5, (1u64 << 32) + 2, 2),
        simulate_batch(&heston, 5, (1u64 << 32) + 2, 2)
    );
}

#[test]
fn every_lane_width_is_bitwise_scalar_on_a_generated_workload() {
    // Legacy default mix plus an all-exotics mix: generated (not
    // hand-built) parameters through every lane width.
    let legacy = GeneratorConfig::small(6, 0.05, 23);
    let exotics = GeneratorConfig {
        payoff_mix: [0.0, 0.0, 0.0, 1.0, 1.0, 1.0],
        ..GeneratorConfig::small(6, 0.05, 29)
    };
    for cfg in [legacy, exotics] {
        for t in &generate(&cfg).tasks {
            let oracle = simulate(t, 11, 101, 1000);
            assert_eq!(simulate_lanes::<4>(t, 11, 101, 1000), oracle, "{t:?}");
            assert_eq!(simulate_lanes::<8>(t, 11, 101, 1000), oracle, "{t:?}");
            assert_eq!(simulate_lanes::<16>(t, 11, 101, 1000), oracle, "{t:?}");
            assert_eq!(simulate_lanes::<32>(t, 11, 101, 1000), oracle, "{t:?}");
        }
    }
}

#[test]
fn kernel_consumes_the_golden_counter_stream() {
    // The "european-lane-block" golden group pins key (7, 42), counters
    // (0..8, 0) — exactly what a European task with id 7 under seed 42
    // consumes for its first 8 paths. Rebuilding the payoff sum from the
    // pinned Box-Muller references must reproduce the kernel's sum (to the
    // f32-vs-f64 reference tolerance), proving the batch kernel feeds the
    // table's counter stream through the table's transform.
    let (name, start, end) = GROUPS[1];
    assert_eq!(name, "european-lane-block");
    let rows = &GOLDEN_RNG[start..end];
    assert_eq!((rows[0].k0, rows[0].k1), (7, 42), "group key drifted from the task");

    let t = task(Payoff::European, 1);
    let stats = simulate_batch(&t, 42, 0, rows.len() as u32);
    assert_eq!(stats, simulate(&t, 42, 0, rows.len() as u32));

    let (s0, k, r, sigma, mat) = (100.0f64, 105.0, 0.05, 0.2, 1.0);
    let drift = (r - 0.5 * sigma * sigma) * mat;
    let vol = sigma * mat.sqrt();
    let expected: f64 = rows
        .iter()
        .map(|g| (s0 * (drift + vol * g.z_ref).exp() - k).max(0.0))
        .sum();
    // Per-path f32 rounding vs the f64 reference, amplified through exp():
    // a loose absolute bound still collapses to zero if the counter stream
    // or key were wrong (samples would be unrelated draws).
    assert!(
        (stats.sum - expected).abs() < 1e-3 * expected.abs().max(1.0) + 8.0 * Z_TOL * 100.0,
        "kernel sum {} vs golden reconstruction {expected}",
        stats.sum
    );
}

#[test]
fn chunked_executor_report_is_unchanged_by_the_batched_kernel() {
    // Executor-level differential: the same allocation executed on two
    // noise-free clusters that differ only in kernel routing must produce
    // the same report to 1e-9 (stats are bit-identical, so in practice the
    // prices agree exactly and latencies are untouched by construction).
    let specs = small_cluster();
    let workload = generate(&GeneratorConfig::small(12, 0.02, 13));
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let cfg = ExecutorConfig {
        chunk_sims: 1 << 14,
        rebalance: RebalanceConfig { enabled: false, ..Default::default() },
        ..Default::default()
    };

    let sim_scalar = SimConfig { kernel: KernelConfig::scalar(), ..SimConfig::exact() };
    let sim_batched = SimConfig::exact(); // batched is the default routing
    assert!(sim_batched.kernel.batch);
    let scalar_cluster = Cluster::simulated(&specs, &sim_scalar, 21).unwrap();
    let batched_cluster = Cluster::simulated(&specs, &sim_batched, 21).unwrap();

    let rs = execute(&scalar_cluster, &workload, &alloc, &cfg).unwrap();
    let rb = execute(&batched_cluster, &workload, &alloc, &cfg).unwrap();

    assert_eq!((rs.failures, rb.failures), (0, 0));
    assert_eq!(rs.chunks, rb.chunks);
    let tol = |x: f64| 1e-9 * x.abs().max(1.0);
    assert!(
        (rs.makespan_secs - rb.makespan_secs).abs() < tol(rs.makespan_secs),
        "makespan {} vs {}",
        rs.makespan_secs,
        rb.makespan_secs
    );
    assert!((rs.cost - rb.cost).abs() < tol(rs.cost));
    for (j, (a, b)) in rs.prices.iter().zip(&rb.prices).enumerate() {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(a.n, b.n, "task {j} path count");
        assert!((a.price - b.price).abs() < 1e-9, "task {j}: {} vs {}", a.price, b.price);
        assert!((a.std_error - b.std_error).abs() < 1e-9, "task {j} std error");
    }
}
