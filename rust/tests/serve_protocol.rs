//! Serve-protocol (v1) integration tests against an ephemeral-port
//! listener: every malformed or unsatisfiable request must come back as a
//! structured `{"v":1,"ok":false,"error":{...}}` payload on the SAME
//! connection — never a dropped connection — and shutdown must answer the
//! requester before the server exits.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;

use cloudshapes::api::{SessionBuilder, TradeoffSession};
use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::partitioner::MilpConfig;
use cloudshapes::platforms::sim::SimConfig;
use cloudshapes::util::json::Json;

struct Server {
    addr: std::net::SocketAddr,
    handle: Option<std::thread::JoinHandle<cloudshapes::Result<()>>>,
}

fn serve_session(session: TradeoffSession) -> Server {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let session = Arc::new(session);
    let handle = std::thread::spawn(move || serve_until_shutdown(listener, session));
    Server { addr, handle: Some(handle) }
}

fn start_server() -> Server {
    serve_session(
        SessionBuilder::quick()
            .milp(MilpConfig { time_limit_secs: 2.0, ..Default::default() })
            .budget_sweep(3)
            .build()
            .unwrap(),
    )
}

/// A server whose simulated cluster is noise-free, so measured execution
/// results are byte-reproducible — required for the cache-coherence
/// assertions of the concurrency stress test.
fn start_exact_server() -> Server {
    let mut cluster = ExperimentConfig::quick().cluster;
    cluster.sim = SimConfig::exact();
    serve_session(
        SessionBuilder::quick()
            .cluster(cluster)
            .milp(MilpConfig { time_limit_secs: 2.0, ..Default::default() })
            .budget_sweep(3)
            .build()
            .unwrap(),
    )
}

impl Server {
    /// One request on a fresh connection.
    fn ask(&self, line: &str) -> Json {
        let mut s = TcpStream::connect(self.addr).unwrap();
        s.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut r = BufReader::new(s);
        let mut resp = String::new();
        r.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap_or_else(|e| panic!("bad response '{resp}': {e}"))
    }

    fn shutdown(mut self) {
        let bye = self.ask(r#"{"v":1,"op":"shutdown"}"#);
        assert_eq!(bye.get("shutdown"), Some(&Json::Bool(true)));
        self.handle.take().unwrap().join().unwrap().unwrap();
    }
}

fn error_kind(resp: &Json) -> Option<&str> {
    resp.get("error")?.get("kind")?.as_str()
}

#[test]
fn bad_requests_get_structured_errors_not_dropped_connections() {
    let server = start_server();

    // All of these arrive on ONE connection, interleaved with a valid ping,
    // proving the connection survives every error.
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ask_same_conn = |line: &str| -> Json {
        stream.write_all(format!("{line}\n").as_bytes()).unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        assert!(!resp.is_empty(), "connection dropped after: {line}");
        Json::parse(resp.trim()).unwrap()
    };

    // Unknown op.
    let r = ask_same_conn(r#"{"v":1,"op":"frobnicate"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(error_kind(&r), Some("protocol"));
    assert!(
        r.get("error").unwrap().get("message").unwrap().as_str().unwrap().contains("frobnicate")
    );

    // Malformed JSON.
    let r = ask_same_conn("{not json at all");
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(error_kind(&r), Some("protocol"));

    // Missing budget on partition/evaluate.
    for op in ["partition", "evaluate"] {
        let r = ask_same_conn(&format!(r#"{{"v":1,"op":"{op}"}}"#));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)), "{op}");
        assert_eq!(error_kind(&r), Some("protocol"), "{op}");
        assert!(
            r.get("error").unwrap().get("message").unwrap().as_str().unwrap().contains("budget"),
            "{op}"
        );
    }

    // Missing / wrong protocol version.
    let r = ask_same_conn(r#"{"op":"ping"}"#);
    assert_eq!(error_kind(&r), Some("protocol"));
    let r = ask_same_conn(r#"{"v":99,"op":"ping"}"#);
    assert_eq!(error_kind(&r), Some("protocol"));

    // Solver-level failure: impossibly tight budget is a typed solver
    // error, still on the same connection.
    let r = ask_same_conn(r#"{"v":1,"op":"partition","partitioner":"milp","budget":1e-9}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(error_kind(&r), Some("solver"));

    // The connection still works after all that.
    let r = ask_same_conn(r#"{"v":1,"op":"ping"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("pong"), Some(&Json::Bool(true)));

    server.shutdown();
}

#[test]
fn partition_and_pareto_roundtrip() {
    let server = start_server();

    let r = server.ask(r#"{"v":1,"op":"specs"}"#);
    assert_eq!(r.get("specs").unwrap().as_arr().unwrap().len(), 3);

    let r = server.ask(r#"{"v":1,"op":"partition","partitioner":"heuristic","budget":null}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
    assert_eq!(r.get("v").unwrap().as_u64(), Some(1));
    assert!(r.get("predicted_latency_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(r.get("budget"), Some(&Json::Null));

    let r = server.ask(r#"{"v":1,"op":"pareto","partitioner":"heuristic"}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
    let points = r.get("points").unwrap().as_arr().unwrap();
    assert!(points.len() >= 2);
    for p in points {
        assert!(p.get("latency_s").unwrap().as_f64().unwrap() > 0.0);
    }

    server.shutdown();
}

#[test]
fn eight_concurrent_clients_see_coherent_cached_results() {
    // Noise-free simulation: identical allocations must produce identical
    // measured results, byte for byte.
    let server = start_exact_server();
    let addr = server.addr;

    // Every client issues the same op sequence on its own connection,
    // concurrently. The shared session cache must hand all of them
    // identical allocations (coherence), with no deadlock and no dropped
    // connection.
    const CLIENTS: usize = 8;
    const REQS: [&str; 4] = [
        r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#,
        // Repeat: guaranteed partition-cache hit for this client.
        r#"{"v":1,"op":"evaluate","partitioner":"heuristic","budget":null}"#,
        r#"{"v":1,"op":"pareto","partitioner":"heuristic"}"#,
        r#"{"v":1,"op":"batch","partitioner":"heuristic","budgets":[null,1000000.0]}"#,
    ];
    let handles: Vec<_> = (0..CLIENTS)
        .map(|client| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                REQS.iter()
                    .map(|req| {
                        stream.write_all(format!("{req}\n").as_bytes()).unwrap();
                        let mut resp = String::new();
                        reader.read_line(&mut resp).unwrap();
                        assert!(!resp.is_empty(), "client {client}: dropped on {req}");
                        let parsed = Json::parse(resp.trim())
                            .unwrap_or_else(|e| panic!("client {client}: bad json {resp}: {e}"));
                        assert_eq!(
                            parsed.get("ok"),
                            Some(&Json::Bool(true)),
                            "client {client}: {req} -> {resp}"
                        );
                        resp.trim().to_string()
                    })
                    .collect::<Vec<String>>()
            })
        })
        .collect();
    let all: Vec<Vec<String>> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Cache coherence: all clients observed byte-identical responses
    // (allocations, predictions, measured execution — the executor is
    // seed-deterministic; JSON serialization is key-ordered).
    for (i, client) in all.iter().enumerate() {
        assert_eq!(client, &all[0], "client {i} observed different results");
    }
    // And a client's repeated evaluate is identical to its first.
    assert_eq!(all[0][0], all[0][1]);

    // The counters prove sharing. Guaranteed even under full contention:
    // each client's repeat-evaluate and its batch `null` entry hit the key
    // that client itself populated earlier on the same connection.
    let r = server.ask(r#"{"v":1,"op":"ping"}"#);
    let cache = r.get("cache").unwrap();
    let hits = cache.get("hits").unwrap().as_u64().unwrap();
    let misses = cache.get("misses").unwrap().as_u64().unwrap();
    assert!(hits >= 2 * CLIENTS as u64, "expected >= {} hits, got {hits}", 2 * CLIENTS);
    // At worst every client raced every miss: 8x the 3 distinct solves.
    assert!(misses <= (3 * CLIENTS) as u64, "implausible miss count {misses}");
    assert!(
        cache.get("partition_entries").unwrap().as_u64().unwrap() >= 2,
        "null + 1e6 budgets should both be cached"
    );

    server.shutdown();
}

#[test]
fn shutdown_while_connected_answers_before_closing() {
    let server = start_server();

    // Hold an open connection, issue shutdown on it, and still read the
    // structured acknowledgement from that same socket.
    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    stream.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    assert_eq!(Json::parse(resp.trim()).unwrap().get("ok"), Some(&Json::Bool(true)));

    stream.write_all(b"{\"v\":1,\"op\":\"shutdown\"}\n").unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let bye = Json::parse(resp.trim()).unwrap();
    assert_eq!(bye.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(bye.get("shutdown"), Some(&Json::Bool(true)));

    let mut server = server;
    server.handle.take().unwrap().join().unwrap().unwrap();
}

#[test]
fn background_run_and_status_over_the_wire() {
    let server = start_exact_server();

    let r = server.ask(r#"{"v":1,"op":"run","partitioner":"heuristic","budget":null}"#);
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{}", r.to_string_compact());
    assert_eq!(r.get("status").unwrap().as_str(), Some("running"));
    let id = r.get("run_id").unwrap().as_u64().unwrap();

    // Poll (on fresh connections — runs are session state, not connection
    // state) until the executor finishes.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(30);
    let done = loop {
        let st = server.ask(&format!(r#"{{"v":1,"op":"status","run_id":{id}}}"#));
        assert_eq!(st.get("ok"), Some(&Json::Bool(true)), "{}", st.to_string_compact());
        match st.get("status").unwrap().as_str() {
            Some("running") => {
                assert!(std::time::Instant::now() < deadline, "run never finished");
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
            Some("done") => break st,
            other => panic!("unexpected state {other:?}"),
        }
    };
    assert!(done.get("measured_latency_s").unwrap().as_f64().unwrap() > 0.0);
    assert!(done.get("measured_cost").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(
        done.get("chunks_done").unwrap().as_u64(),
        done.get("chunks_total").unwrap().as_u64()
    );
    assert_eq!(done.get("tasks_priced").unwrap().as_u64(), Some(8));
    assert_eq!(done.get("failures").unwrap().as_u64(), Some(0));

    // Unknown run id: structured protocol error.
    let r = server.ask(r#"{"v":1,"op":"status","run_id":999999}"#);
    assert_eq!(error_kind(&r), Some("protocol"));

    server.shutdown();
}

#[test]
fn streaming_run_emits_events_then_final_response() {
    let server = start_exact_server();

    let mut stream = TcpStream::connect(server.addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    stream
        .write_all(b"{\"v\":1,\"op\":\"run\",\"partitioner\":\"heuristic\",\"budget\":null,\"stream\":true}\n")
        .unwrap();

    // Interim lines carry an "event" key and never "ok"; the final line is
    // the normal success envelope.
    let mut events: Vec<Json> = Vec::new();
    let fin = loop {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(!line.is_empty(), "connection dropped mid-stream");
        let parsed = Json::parse(line.trim()).unwrap();
        assert_eq!(parsed.get("v").unwrap().as_u64(), Some(1));
        if parsed.get("event").is_some() {
            assert!(parsed.get("ok").is_none(), "events must not look like responses");
            events.push(parsed);
        } else {
            break parsed;
        }
    };
    assert_eq!(fin.get("ok"), Some(&Json::Bool(true)), "{}", fin.to_string_compact());
    assert!(fin.get("measured_latency_s").unwrap().as_f64().unwrap() > 0.0);
    assert_eq!(fin.get("failures").unwrap().as_u64(), Some(0));

    let kinds: Vec<&str> =
        events.iter().map(|e| e.get("event").unwrap().as_str().unwrap()).collect();
    assert_eq!(kinds.first(), Some(&"started"), "{kinds:?}");
    assert_eq!(
        events.iter().filter(|e| e.get("event").unwrap().as_str() == Some("task_priced")).count(),
        8,
        "every quick-workload task must stream its price: {kinds:?}"
    );

    // The connection still serves normal requests after a stream.
    stream.write_all(b"{\"v\":1,\"op\":\"ping\"}\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(line.trim()).unwrap().get("ok"), Some(&Json::Bool(true)));

    // A streaming run with an infeasible budget fails with a single
    // structured error line (no interim garbage left unterminated).
    stream
        .write_all(b"{\"v\":1,\"op\":\"run\",\"partitioner\":\"milp\",\"budget\":1e-9,\"stream\":true}\n")
        .unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let err = Json::parse(line.trim()).unwrap();
    assert_eq!(err.get("ok"), Some(&Json::Bool(false)));
    assert_eq!(error_kind(&err), Some("solver"));

    server.shutdown();
}
