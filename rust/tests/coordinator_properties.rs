//! Property tests on the coordinator's invariants (prop-harness replaces
//! proptest, which is unavailable offline — see testing::prop).

use cloudshapes::coordinator::executor::{execute, ExecutorConfig};
use cloudshapes::coordinator::partitioner::baselines::{Classic, ClassicPartitioner};
use cloudshapes::coordinator::partitioner::{lower_cost_bound, MilpConfig, MilpPartitioner};
use cloudshapes::coordinator::{sweep, HeuristicPartitioner, ModelSet, Partitioner, SweepConfig};
use cloudshapes::models::{CostModel, LatencyModel};
use cloudshapes::platforms::{Cluster, SimConfig};
use cloudshapes::testing::prop::{prop_assert, prop_check, Gen};
use cloudshapes::workload::{generate, GeneratorConfig};

/// Random, economically plausible model set (sized by the generator).
fn arb_models(g: &mut Gen) -> ModelSet {
    let mu = g.usize(1, 6);
    let tau = g.usize(1, 10);
    let quanta = [60.0, 600.0, 3600.0];
    let mut latency = Vec::new();
    for _ in 0..mu {
        // Platform-wide speed scale; per-task jitter on top.
        let speed = g.log_uniform(1e-7, 1e-4);
        let gamma = g.log_uniform(0.1, 60.0);
        for _ in 0..tau {
            latency.push(LatencyModel::new(speed * g.f64(0.5, 2.0), gamma * g.f64(0.5, 2.0)));
        }
    }
    let cost: Vec<CostModel> = (0..mu)
        .map(|_| CostModel::new(*g.rng.choose(&quanta), g.f64(0.05, 2.0)).unwrap())
        .collect();
    let n: Vec<u64> = (0..tau).map(|_| g.rng.range_u64(10_000, 50_000_000)).collect();
    ModelSet::new(latency, cost, n, (0..mu).map(|i| format!("p{i}")).collect())
}

fn fast_milp() -> MilpPartitioner {
    MilpPartitioner::new(MilpConfig { max_nodes: 40, time_limit_secs: 1.0, ..Default::default() })
}

#[test]
fn prop_all_partitioners_produce_valid_allocations() {
    prop_check("partitioners produce valid allocations", 40, |g| {
        let models = arb_models(g);
        let milp = fast_milp();
        let heuristic = HeuristicPartitioner::default();
        let classics: Vec<ClassicPartitioner> =
            Classic::all().into_iter().map(ClassicPartitioner).collect();
        let mut parts: Vec<&dyn Partitioner> = vec![&milp, &heuristic];
        for c in &classics {
            parts.push(c);
        }
        for part in parts {
            let alloc = part
                .partition(&models, None)
                .map_err(|e| format!("{}: {e}", part.name()))?;
            alloc.validate().map_err(|e| format!("{}: {e}", part.name()))?;
        }
        Ok(())
    });
}

#[test]
fn prop_milp_never_worse_than_heuristic() {
    // The paper's headline claim, as a property over random problems.
    prop_check("milp <= heuristic at matched budgets", 25, |g| {
        let models = arb_models(g);
        let heuristic = HeuristicPartitioner::default();
        let h_alloc = heuristic.partition(&models, None)?;
        let (h_lat, h_cost) = models.evaluate(&h_alloc);
        let milp = fast_milp();
        let m = milp.solve(&models, Some(h_cost))?;
        prop_assert(
            m.makespan <= h_lat * (1.0 + 1e-6),
            &format!("milp {} > heuristic {h_lat} at budget {h_cost}", m.makespan),
        )
    });
}

#[test]
fn prop_milp_respects_budgets() {
    prop_check("milp cost <= budget (true ceiling semantics)", 25, |g| {
        let models = arb_models(g);
        let (c_l, _) = lower_cost_bound(&models);
        let budget = c_l * g.f64(1.0, 4.0) + g.f64(0.0, 2.0);
        match fast_milp().solve(&models, Some(budget)) {
            Ok(out) => prop_assert(
                out.cost <= budget + 1e-9 && out.bound <= out.makespan + 1e-9,
                &format!("cost {} budget {budget} bound {}", out.cost, out.bound),
            ),
            Err(_) => prop_assert(c_l > budget, "infeasible although C_L fits"),
        }
    });
}

#[test]
fn prop_makespan_is_max_platform_latency() {
    prop_check("F_L == max_i G_L_i", 60, |g| {
        let models = arb_models(g);
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let max = (0..models.mu)
            .map(|i| models.platform_latency(&alloc, i))
            .fold(0.0f64, f64::max);
        prop_assert((models.makespan(&alloc) - max).abs() < 1e-9, "makespan mismatch")
    });
}

#[test]
fn prop_total_cost_is_sum_of_quantised_platform_costs() {
    prop_check("F_C == sum of ceil-quantised costs", 60, |g| {
        let models = arb_models(g);
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let total: f64 = (0..models.mu).map(|i| models.platform_cost(&alloc, i)).sum();
        prop_assert((models.total_cost(&alloc) - total).abs() < 1e-9, "cost mismatch")?;
        prop_assert(
            models.total_cost_relaxed(&alloc) <= total + 1e-9,
            "relaxed cost above billed",
        )
    });
}

#[test]
fn prop_pareto_fronts_are_monotone() {
    prop_check("pareto front monotone in (cost, latency)", 10, |g| {
        let models = arb_models(g);
        let curve = sweep(
            &HeuristicPartitioner::default(),
            &models,
            &SweepConfig { levels: g.usize(2, 6) },
        )?;
        let front = curve.pareto_front();
        for w in front.windows(2) {
            prop_assert(
                w[0].cost <= w[1].cost + 1e-9 && w[0].latency >= w[1].latency - 1e-9,
                "front not monotone",
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_executor_preserves_simulation_totals() {
    prop_check("executor dispatches exactly N sims per task", 12, |g| {
        let n_tasks = g.usize(1, 6);
        let workload = generate(&GeneratorConfig::small(n_tasks, 0.1, g.rng.next_u64()));
        let specs = cloudshapes::platforms::spec::small_cluster();
        let cluster = Cluster::simulated(&specs, &SimConfig::exact(), g.rng.next_u64())?;
        let models = ModelSet::from_specs(&specs, &workload);
        let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default())?;
        let dispatched: u64 = rep.platforms.iter().map(|p| p.sims).sum();
        prop_assert(
            dispatched == workload.total_sims(),
            &format!("{dispatched} != {}", workload.total_sims()),
        )?;
        let max_lane = rep.platforms.iter().map(|p| p.latency_secs).fold(0.0f64, f64::max);
        prop_assert((rep.makespan_secs - max_lane).abs() < 1e-9, "makespan != max lane")
    });
}

#[test]
fn partial_platform_failures_are_survivable() {
    // Failure injection: a flaky platform loses slices but the run
    // completes, reports failures, and the other platforms' prices arrive.
    let specs = cloudshapes::platforms::spec::small_cluster();
    let flaky = SimConfig { failure_rate: 0.5, ..SimConfig::exact() };
    let cluster = Cluster::simulated(&specs, &flaky, 11).unwrap();
    let workload = generate(&GeneratorConfig::small(10, 0.1, 3));
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
    // With the chunked executor's default retries most injected failures
    // are absorbed as retries; either way the injection must be visible.
    assert!(
        rep.failures + rep.retries > 0,
        "failure injection never fired at rate 0.5"
    );
    assert!(rep.failures < 30, "everything failed");
    // Some tasks should still be priced by surviving slices.
    assert!(rep.prices.iter().any(Option::is_some));
}

#[test]
fn benchmarking_under_failures_keeps_partitioning_usable() {
    // A platform failing 30% of benchmark runs still gets a usable model
    // from the surviving reps; end-to-end partitioning succeeds.
    let specs = cloudshapes::platforms::spec::small_cluster();
    let flaky = SimConfig { failure_rate: 0.3, ..SimConfig::default() };
    let cluster = Cluster::simulated(&specs, &flaky, 5).unwrap();
    let workload = generate(&GeneratorConfig::small(5, 0.05, 9));
    let report = cloudshapes::coordinator::benchmark(
        &cluster,
        &workload,
        &cloudshapes::coordinator::BenchmarkConfig { reps: 5, ..Default::default() },
    );
    let alloc = fast_milp().partition(&report.models, None).unwrap();
    assert!(alloc.validate().is_ok());
}
