//! Golden-value pricing tests: the closed-form Black-Scholes oracle
//! (`pricing/blackscholes.rs`) against the native Monte Carlo pricer
//! (`pricing/mc.rs`) on the Kaiserslautern-style paper workload, within
//! 3σ standard-error bounds.
//!
//! Everything is seed-pinned through `util::rng` (the generator draws the
//! tasks from seed 2015 — the paper workload — and the MC kernels are
//! counter-based), so these are deterministic golden tests, not flaky
//! statistical ones: the realised z-scores are fixed by the seeds. A small
//! absolute cushion (±$0.02) on top of 3σ absorbs the f32 payoff
//! quantisation of the kernel-mirroring MC path.

use cloudshapes::pricing::{blackscholes, mc};
use cloudshapes::workload::{generate, GeneratorConfig, Payoff};

/// Seed for the MC counter streams (distinct from the generator seed so the
/// draws are independent of the task parameters).
const MC_SEED: u32 = 2015;

#[test]
fn european_kaiserslautern_options_match_black_scholes_within_3_sigma() {
    // The paper workload: 128 tasks drawn from the Kaiserslautern ranges.
    let w = generate(&GeneratorConfig::default());
    let mut checked = 0;
    for t in w.tasks.iter().filter(|t| t.payoff == Payoff::European).take(12) {
        let est = mc::price(t, MC_SEED, 1 << 16);
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        let tol = 3.0 * est.std_error + 0.02;
        assert!(
            (est.price - bs).abs() <= tol,
            "task {}: mc {} ± {} vs closed form {bs} (|Δ| > 3σ + 0.02)",
            t.id,
            est.price,
            est.std_error
        );
        assert!(est.std_error > 0.0 && est.n == 1 << 16);
        checked += 1;
    }
    assert!(checked >= 8, "paper workload should contain European tasks, saw {checked}");
}

#[test]
fn asian_kaiserslautern_options_bracketed_by_closed_forms_within_3_sigma() {
    // No closed form for the arithmetic Asian — but Kemna-Vorst's geometric
    // call is a strict lower bound and the European call an upper bound.
    let w = generate(&GeneratorConfig::default());
    let mut checked = 0;
    for t in w.tasks.iter().filter(|t| t.payoff == Payoff::Asian).take(3) {
        let est = mc::price(t, MC_SEED, 1 << 12);
        let geo = blackscholes::geometric_asian_call(
            t.spot, t.strike, t.rate, t.sigma, t.maturity, t.steps,
        );
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            est.price >= geo - 3.0 * est.std_error - 0.02,
            "task {}: arithmetic Asian {} ± {} below geometric bound {geo}",
            t.id,
            est.price,
            est.std_error
        );
        assert!(
            est.price <= eur + 3.0 * est.std_error + 0.02,
            "task {}: Asian {} ± {} above European bound {eur}",
            t.id,
            est.price,
            est.std_error
        );
        checked += 1;
    }
    assert!(checked >= 1, "paper workload should contain Asian tasks");
}

#[test]
fn barrier_kaiserslautern_options_stay_below_european_within_3_sigma() {
    // An up-and-out barrier call is dominated by the European call.
    let w = generate(&GeneratorConfig::default());
    let mut checked = 0;
    for t in w.tasks.iter().filter(|t| t.payoff == Payoff::Barrier).take(3) {
        let est = mc::price(t, MC_SEED, 1 << 12);
        let eur = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            est.price <= eur + 3.0 * est.std_error + 0.02,
            "task {}: barrier {} ± {} above European {eur}",
            t.id,
            est.price,
            est.std_error
        );
        assert!(est.price >= 0.0);
        checked += 1;
    }
    assert!(checked >= 1, "paper workload should contain Barrier tasks");
}
