//! Recovery semantics of the event-driven chunked executor:
//!
//! - **equivalence**: with a noise-free simulator, rebalancing off and no
//!   failures, the chunked scheduler reproduces the one-shot
//!   (`execute_static`) report — makespan, cost and prices — to 1e-9;
//! - **failure recovery**: with `failure_rate` in (0,1) and retries on,
//!   every task keeps a price estimate within confidence bounds; with
//!   retries off, failures zero out slices exactly like the legacy
//!   executor reported them;
//! - **straggler rebalancing**: a lane with a hidden 5× throughput factor
//!   (invisible to the models) loses its queued chunks to healthy lanes,
//!   cutting the realised makespan vs the static executor;
//! - **u64 offsets**: tasks beyond 2^32 simulations keep counter-disjoint
//!   slices (the old `% u32::MAX` truncation overlapped RNG ranges).

use std::sync::Arc;

use cloudshapes::coordinator::executor::{
    execute, execute_static, execute_with, ExecutorConfig, RebalanceConfig, RetryConfig,
};
use cloudshapes::coordinator::{Allocation, HeuristicPartitioner, ModelSet};
use cloudshapes::platforms::spec::small_cluster;
use cloudshapes::platforms::{Cluster, Platform, SimConfig, SimPlatform};
use cloudshapes::pricing::blackscholes;
use cloudshapes::workload::option::{OptionTask, Payoff};
use cloudshapes::workload::{generate, GeneratorConfig, Workload};

fn exact_setup(n_tasks: usize) -> (Cluster, Workload, ModelSet) {
    let specs = small_cluster();
    let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 21).unwrap();
    let workload = generate(&GeneratorConfig::small(n_tasks, 0.02, 13));
    let models = ModelSet::from_specs(&specs, &workload);
    (cluster, workload, models)
}

/// Chunk finely and disable rebalancing — the configuration the equivalence
/// guarantee is stated for.
fn chunked_cfg() -> ExecutorConfig {
    ExecutorConfig {
        chunk_sims: 1 << 15,
        rebalance: RebalanceConfig { enabled: false, ..Default::default() },
        ..Default::default()
    }
}

#[test]
fn chunked_reproduces_static_execution_to_1e9() {
    let (cluster, workload, models) = exact_setup(16);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let rs = execute_static(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
    let rc = execute(&cluster, &workload, &alloc, &chunked_cfg()).unwrap();

    assert!(rc.chunks > rs.chunks, "chunking must split slices ({} vs {})", rc.chunks, rs.chunks);
    assert_eq!((rc.failures, rc.retries, rc.migrations), (0, 0, 0));
    let tol = |x: f64| 1e-9 * x.abs().max(1.0);
    assert!(
        (rs.makespan_secs - rc.makespan_secs).abs() < tol(rs.makespan_secs),
        "makespan {} vs {}",
        rs.makespan_secs,
        rc.makespan_secs
    );
    assert!((rs.cost - rc.cost).abs() < tol(rs.cost), "cost {} vs {}", rs.cost, rc.cost);
    for (i, (a, b)) in rs.platforms.iter().zip(&rc.platforms).enumerate() {
        assert!(
            (a.latency_secs - b.latency_secs).abs() < tol(a.latency_secs),
            "platform {i} lane time {} vs {}",
            a.latency_secs,
            b.latency_secs
        );
        assert_eq!(a.sims, b.sims, "platform {i} sims");
        assert_eq!(a.quanta, b.quanta, "platform {i} quanta");
    }
    for (j, (a, b)) in rs.prices.iter().zip(&rc.prices).enumerate() {
        let (a, b) = (a.as_ref().unwrap(), b.as_ref().unwrap());
        assert_eq!(a.n, b.n, "task {j} path count");
        assert!((a.price - b.price).abs() < 1e-9, "task {j}: {} vs {}", a.price, b.price);
        assert!((a.std_error - b.std_error).abs() < 1e-9, "task {j} std error");
    }

    // Rebalancing left on must be a no-op when nothing drifts from the
    // model (exact simulator): still the same report.
    let on = ExecutorConfig {
        rebalance: RebalanceConfig { enabled: true, ..Default::default() },
        ..chunked_cfg()
    };
    let rr = execute_with(&cluster, &workload, &alloc, &on, Some(&models), &mut |_| {}).unwrap();
    assert_eq!(rr.migrations, 0, "exact sim must not trigger migrations");
    assert!((rr.makespan_secs - rs.makespan_secs).abs() < tol(rs.makespan_secs));
}

#[test]
fn failures_with_retries_never_lose_a_price() {
    // The acceptance bar: failure_rate 0.3, retries on -> zero tasks lose
    // their estimate, and surviving statistics stay unbiased.
    let specs = small_cluster();
    let cluster = Cluster::simulated(
        &specs,
        &SimConfig { failure_rate: 0.3, ..SimConfig::exact() },
        77,
    ).unwrap();
    let workload = generate(&GeneratorConfig {
        n_tasks: 8,
        seed: 5,
        accuracy: 0.02,
        payoff_mix: Payoff::European.one_hot_mix(), // closed-form checkable
        step_choices: vec![64],
        ..GeneratorConfig::default()
    });
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let cfg = ExecutorConfig {
        chunk_sims: 1 << 15,
        retry: RetryConfig { max_attempts: 6, rehome: true },
        ..Default::default()
    };
    let rep = execute(&cluster, &workload, &alloc, &cfg).unwrap();
    assert!(rep.retries > 0, "a 30% failure rate must trigger retries");
    for (t, price) in workload.tasks.iter().zip(&rep.prices) {
        let est = price.as_ref().unwrap_or_else(|| panic!("task {} lost its price", t.id));
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            (est.price - bs).abs() < 6.0 * est.std_error + 0.1,
            "task {}: {est:?} vs bs {bs}",
            t.id
        );
    }
}

#[test]
fn failures_without_retries_match_legacy_reporting() {
    // max_attempts 1 + one chunk per slice IS the legacy executor: each
    // failed slice is one reported failure and its paths are gone.
    let specs = small_cluster();
    let cluster = Cluster::simulated(
        &specs,
        &SimConfig { failure_rate: 0.3, ..SimConfig::exact() },
        77,
    ).unwrap();
    let workload = generate(&GeneratorConfig::small(8, 0.02, 5));
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let cfg = ExecutorConfig {
        chunk_sims: 0, // one chunk per slice
        retry: RetryConfig { max_attempts: 1, rehome: false },
        rebalance: RebalanceConfig { enabled: false, ..Default::default() },
        ..Default::default()
    };
    let rep = execute(&cluster, &workload, &alloc, &cfg).unwrap();
    assert_eq!(rep.retries, 0);
    let recorded_errors: usize = rep.platforms.iter().map(|p| p.errors.len()).sum();
    assert_eq!(rep.failures, recorded_errors, "every failed slice reports exactly once");
    assert!(rep.failures > 0, "0.3 failure rate across 24 slices should fail something");
}

#[test]
fn straggler_rebalancing_cuts_makespan() {
    // One platform is secretly 5x slower than every model believes. The
    // static executor eats the full straggler lane; rebalancing migrates
    // its queued chunks onto healthy lanes.
    let specs = small_cluster();
    let straggler = specs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.app_gflops.total_cmp(&b.1.app_gflops))
        .map(|(i, _)| i)
        .unwrap();
    let platforms: Vec<Arc<dyn Platform>> = specs
        .iter()
        .enumerate()
        .map(|(i, s)| -> Arc<dyn Platform> {
            if i == straggler {
                Arc::new(SimPlatform::with_hidden_factor(
                    s.clone(),
                    SimConfig::exact(),
                    21 + i as u64,
                    5.0,
                ))
            } else {
                Arc::new(SimPlatform::new(s.clone(), SimConfig::exact(), 21 + i as u64))
            }
        })
        .collect();
    let cluster = Cluster::new(platforms).unwrap();
    let workload = generate(&GeneratorConfig::small(8, 0.02, 13));
    // Nominal models: they still think the straggler is fast, so the
    // allocation loads it heavily — exactly the Fig. 3 gap scenario.
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);

    let chunked_off = ExecutorConfig {
        chunk_sims: 1 << 14,
        rebalance: RebalanceConfig { enabled: false, ..Default::default() },
        ..Default::default()
    };
    let chunked_on = ExecutorConfig {
        rebalance: RebalanceConfig { enabled: true, tolerance: 0.25 },
        ..chunked_off.clone()
    };
    let stat = execute_static(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
    let off =
        execute_with(&cluster, &workload, &alloc, &chunked_off, Some(&models), &mut |_| {})
            .unwrap();
    let on = execute_with(&cluster, &workload, &alloc, &chunked_on, Some(&models), &mut |_| {})
        .unwrap();

    // Without rebalancing, chunking alone does not save the makespan.
    assert!((off.makespan_secs - stat.makespan_secs).abs() < 1e-6 * stat.makespan_secs);
    assert!(on.migrations > 0, "the drifting lane must shed work");
    assert!(
        on.makespan_secs < 0.75 * stat.makespan_secs,
        "rebalancing should cut the straggler makespan: {} vs static {}",
        on.makespan_secs,
        stat.makespan_secs
    );
    // Work conservation: every task still fully priced.
    assert!(on.prices.iter().all(Option::is_some));
    assert_eq!(on.failures, 0);
}

#[test]
fn u64_offsets_keep_giant_tasks_unbiased() {
    // A single task with 2^33 simulations split across two platforms: the
    // second slice's offset (2^32) used to truncate into the first slice's
    // counter range. Virtual latency makes this cheap to actually run.
    let specs: Vec<_> = small_cluster().into_iter().take(2).collect();
    let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 9).unwrap();
    let task = OptionTask {
        id: 0,
        payoff: Payoff::European,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        barrier: 0.0,
        steps: 1,
        target_accuracy: 1e-4,
        n_sims: 1 << 33,
        ..OptionTask::default()
    };
    let workload = Workload::new(vec![task.clone()]);
    let alloc = Allocation::proportional(2, 1, &[1.0, 1.0]);
    let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
    assert_eq!(rep.failures, 0);
    assert_eq!(rep.platforms[0].sims + rep.platforms[1].sims, 1 << 33);
    let est = rep.prices[0].as_ref().unwrap();
    let bs = blackscholes::call(task.spot, task.strike, task.rate, task.sigma, task.maturity);
    assert!(
        (est.price - bs).abs() < 6.0 * est.std_error + 0.05,
        "{est:?} vs bs {bs}"
    );
    // Both platforms contributed statistics (disjoint high/low ranges).
    assert!(est.n > (1 << 15), "both slices' stats should merge, got {}", est.n);
}
