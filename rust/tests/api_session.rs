//! `api::TradeoffSession` builder contract tests plus an end-to-end smoke
//! `evaluate()` on the small simulated cluster.

use cloudshapes::api::{CloudshapesError, PartitionerRegistry, SessionBuilder};
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::partitioner::{lower_cost_bound, MilpConfig};
use cloudshapes::coordinator::{Allocation, ModelSet, Partitioner};
use cloudshapes::workload::GeneratorConfig;

#[test]
fn build_requires_cluster_and_workload() {
    let e = SessionBuilder::new().build().unwrap_err();
    assert!(matches!(e, CloudshapesError::Config(_)), "{e}");
    assert!(e.message().contains("cluster"), "{e}");

    let e = SessionBuilder::new()
        .cluster(ExperimentConfig::quick().cluster)
        .build()
        .unwrap_err();
    assert!(matches!(e, CloudshapesError::Config(_)), "{e}");
    assert!(e.message().contains("workload"), "{e}");
}

#[test]
fn build_rejects_unregistered_partitioner_before_benchmarking() {
    let cfg = ExperimentConfig::quick();
    let e = SessionBuilder::new()
        .cluster(cfg.cluster)
        .workload(cfg.workload)
        .partitioner("does-not-exist")
        .build()
        .unwrap_err();
    assert_eq!(e.kind(), "config");
    assert!(e.message().contains("does-not-exist"), "{e}");
    // The error helps: it lists what IS registered.
    assert!(e.message().contains("heuristic"), "{e}");
}

#[test]
fn unknown_partitioner_at_call_time_is_config_error() {
    let session = SessionBuilder::quick().build().unwrap();
    let e = session.partition_with(Some("nope"), None).unwrap_err();
    assert_eq!(e.kind(), "config");
    let e = session.pareto_frontier_with(Some("nope")).unwrap_err();
    assert_eq!(e.kind(), "config");
}

#[test]
fn explicit_builder_matches_issue_shape_and_evaluates() {
    // The ISSUE's canonical call shape: cluster + workload + partitioner +
    // budget_sweep, then pareto_frontier / evaluate.
    let cfg = ExperimentConfig::quick();
    let session = SessionBuilder::new()
        .cluster(cfg.cluster.clone())
        .workload(GeneratorConfig::small(6, 0.03, 11))
        .partitioner("heuristic")
        .budget_sweep(4)
        .milp(MilpConfig { time_limit_secs: 2.0, ..Default::default() })
        .build()
        .unwrap();

    assert_eq!(session.default_partitioner(), "heuristic");
    assert_eq!(session.workload().len(), 6);
    assert_eq!(session.models().mu, 3);

    // Smoke evaluate: unconstrained, then at a real midpoint budget.
    let ev = session.evaluate(None).unwrap();
    assert_eq!(ev.execution.failures, 0);
    assert!(ev.execution.makespan_secs > 0.0);
    assert!(ev.partition.alloc.validate().is_ok());
    let rel = (ev.execution.makespan_secs - ev.partition.predicted_latency_s).abs()
        / ev.partition.predicted_latency_s;
    assert!(rel < 0.5, "prediction off by {rel}");

    let (c_l, _) = lower_cost_bound(session.models());
    let budget = c_l + (ev.partition.predicted_cost - c_l).max(0.0) / 2.0;
    let constrained = session.evaluate(Some(budget)).unwrap();
    assert!(constrained.partition.predicted_cost <= budget + 1e-9);

    // The frontier brackets the budgets and stays valid.
    let curve = session.pareto_frontier().unwrap();
    assert!(curve.points.len() >= 2);
    assert!(curve.c_lower <= curve.c_upper + 1e-9);
    for p in &curve.points {
        assert!(p.alloc.validate().is_ok());
    }
}

#[test]
fn custom_strategy_plugs_in_through_the_builder() {
    // A strategy the coordinator has never heard of, registered by name.
    struct CheapestOnly;
    impl Partitioner for CheapestOnly {
        fn name(&self) -> &str {
            "cheapest-only"
        }
        fn partition(
            &self,
            models: &ModelSet,
            _budget: Option<f64>,
        ) -> cloudshapes::Result<Allocation> {
            Ok(lower_cost_bound(models).1)
        }
    }

    let session = SessionBuilder::quick()
        .register("cheapest-only", |_| Box::new(CheapestOnly))
        .partitioner("cheapest-only")
        .build()
        .unwrap();
    let p = session.partition(None).unwrap();
    assert_eq!(p.partitioner, "cheapest-only");
    assert_eq!(p.alloc.used_platforms().len(), 1);
}

#[test]
fn registry_is_replaceable() {
    let mut registry = PartitionerRegistry::empty();
    registry.register("only", |cfg| {
        Box::new(cloudshapes::coordinator::MilpPartitioner::new(cfg.milp.clone()))
    });
    let e = SessionBuilder::quick()
        .registry(registry)
        .partitioner("milp") // not in the replacement registry
        .build()
        .unwrap_err();
    assert_eq!(e.kind(), "config");
}
