//! Hybrid-cluster integration: simulated platforms + the native PJRT
//! platform in one executor run (requires `make artifacts`).

use std::path::PathBuf;
use std::sync::Arc;

use cloudshapes::coordinator::executor::{execute, ExecutorConfig};
use cloudshapes::coordinator::{benchmark, BenchmarkConfig, HeuristicPartitioner, ModelSet};
use cloudshapes::platforms::native::NativePlatform;
use cloudshapes::platforms::spec::small_cluster;
use cloudshapes::platforms::{ChunkCtx, Cluster, Platform, SimConfig};
use cloudshapes::pricing::blackscholes;
use cloudshapes::runtime::EngineHandle;
use cloudshapes::workload::option::Payoff;
use cloudshapes::workload::{generate, GeneratorConfig};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn hybrid_cluster() -> Cluster {
    let mut cluster = Cluster::simulated(&small_cluster(), &SimConfig::exact(), 3).unwrap();
    let engine = EngineHandle::spawn(&artifact_dir()).expect("make artifacts first");
    cluster.push(Arc::new(NativePlatform::new(engine))).unwrap();
    cluster
}

#[test]
fn native_platform_measures_real_wallclock() {
    let cluster = hybrid_cluster();
    let native = cluster.platform(3);
    let w = generate(&GeneratorConfig::small(1, 0.05, 1));
    let mut t = w.tasks[0].clone();
    t.payoff = Payoff::European;
    t.steps = 1;
    let _warmup = native.execute(&t, 1 << 12, 1, ChunkCtx::cold(0)); // lazy compile happens here
    let small = native.execute(&t, 1 << 12, 1, ChunkCtx::cold(0));
    let big = native.execute(&t, 1 << 19, 1, ChunkCtx::cold(0));
    assert!(small.error.is_none() && big.error.is_none());
    assert!(big.latency_secs > small.latency_secs, "more paths must take longer");
    assert!(big.stats.unwrap().n >= 1 << 19);
}

#[test]
fn hybrid_execution_prices_correctly_and_uses_native() {
    let cluster = hybrid_cluster();
    // European-only workload so every price is closed-form checkable.
    let workload = generate(&GeneratorConfig {
        n_tasks: 4,
        seed: 5,
        accuracy: 0.05,
        payoff_mix: Payoff::European.one_hot_mix(),
        step_choices: vec![64],
        ..GeneratorConfig::default()
    });
    // Benchmark the hybrid cluster (native rungs burn real wall-clock, so
    // keep the ladder modest) and partition with the fitted models.
    let cfg = BenchmarkConfig { reps: 2, rung_budget_secs: 5.0, ..Default::default() };
    let models: ModelSet = benchmark(&cluster, &workload, &cfg).models;
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
    assert_eq!(rep.failures, 0);
    // Native platform (a real CPU vs simulated-seconds platforms) should
    // have received a share of the work.
    let native_report = rep.platforms.iter().find(|p| p.name.contains("native")).unwrap();
    assert!(native_report.sims > 0, "native platform got no work");
    for (t, price) in workload.tasks.iter().zip(&rep.prices) {
        let est = price.as_ref().unwrap();
        let bs = blackscholes::call(t.spot, t.strike, t.rate, t.sigma, t.maturity);
        assert!(
            (est.price - bs).abs() < 6.0 * est.std_error + 0.1,
            "task {}: {est:?} vs {bs}",
            t.id
        );
    }
}

#[test]
fn native_failure_path_reports_not_panics() {
    // An engine pointed at a payoff with artifacts missing must fail
    // gracefully through the ExecOutcome error channel.
    let engine = EngineHandle::spawn(&artifact_dir()).unwrap();
    let native = NativePlatform::new(engine);
    let mut t = generate(&GeneratorConfig::small(1, 0.05, 1)).tasks[0].clone();
    t.payoff = Payoff::Asian;
    t.steps = 64;
    let out = native.execute(&t, 4096, 1, ChunkCtx::cold(0));
    // Asian artifacts exist, so this succeeds — now a nonexistent dir:
    assert!(out.error.is_none());
    assert!(EngineHandle::spawn(std::path::Path::new("/nonexistent-artifacts")).is_err());
}
