//! Regenerates **Table IV**: latency-cost trade-off, heuristic vs ILP, at
//! the cheapest (C_L), median (C_k) and fastest (C_U) cost levels — the
//! paper's headline comparison (heuristic/ILP ratios up to 1.57× cost and
//! 2.11× latency; never below 1.0).

mod common;

use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::{self, Experiment};

fn main() {
    let cfg = ExperimentConfig::default();
    let (e, _) = common::timed("build paper experiment", || {
        Experiment::build(cfg.clone()).expect("experiment")
    });
    let (rows, _) = common::timed("table4 (heuristic + 2 MILP solves)", || {
        report::table4_rows(e.models(), &cfg.milp).expect("table4")
    });
    let table = report::table4(e.models(), &cfg.milp).expect("render");
    let rendered = table.render();
    println!("\n{rendered}");
    common::save("table4.txt", &rendered);
    common::save("table4.csv", &table.to_csv());

    println!("paper shape checks:");
    // C_L: both approaches identical (all work on the cheapest platform).
    assert!((rows[0].heuristic_latency - rows[0].milp_latency).abs() < 1e-9);
    assert!((rows[0].heuristic_cost - rows[0].milp_cost).abs() < 1e-9);
    println!("  C_L identical: OK");
    // ILP never worse than the heuristic at any level.
    for r in &rows {
        assert!(
            r.milp_latency <= r.heuristic_latency * 1.001,
            "{}: milp {} vs heuristic {}",
            r.level,
            r.milp_latency,
            r.heuristic_latency
        );
    }
    println!("  ILP >= heuristic everywhere: OK");
    // Strict improvement at median and C_U (paper: 1.73x / 2.11x).
    let median_ratio = rows[1].heuristic_latency / rows[1].milp_latency;
    let cu_ratio = rows[2].heuristic_latency / rows[2].milp_latency;
    println!("  latency ratio at median: {median_ratio:.2}x (paper: 1.73x)");
    println!("  latency ratio at C_U:    {cu_ratio:.2}x (paper: 2.11x)");
    assert!(median_ratio > 1.2, "median improvement too small: {median_ratio}");
    assert!(cu_ratio > 1.2, "C_U improvement too small: {cu_ratio}");
    println!("table4 bench OK");
}
