//! Perf: the cluster executor — static (one-shot) vs chunked vs
//! chunked+rebalance on the paper workload (noise-free sim), a straggler
//! recovery scenario, and the Monte Carlo kernel's paths/second, scalar
//! vs batched, for all six payoff families. Each exotic family clears an
//! independent oracle gate (LSMC vs binomial tree, basket vs
//! moment-matched lognormal, degenerate Heston vs Black-Scholes) before
//! its throughput is published. Emits `results/BENCH_executor.json`
//! (executor trajectory) and `results/BENCH_kernel.json` (kernel
//! throughput gate) so the perf trajectory is tracked across PRs.
//!
//! Pass `--smoke` (the CI mode) to shrink the workload so the bench acts as
//! a fast equivalence/regression gate rather than a measurement session.

mod common;

use std::sync::Arc;

use cloudshapes::coordinator::executor::{
    execute, execute_static, execute_with, ExecEvent, ExecutorConfig, RebalanceConfig,
};
use cloudshapes::coordinator::{HeuristicPartitioner, ModelSet};
use cloudshapes::obs::{self, MetricsRegistry};
use cloudshapes::platforms::spec::{paper_cluster, small_cluster};
use cloudshapes::platforms::{Cluster, Platform, SimConfig, SimPlatform};
use cloudshapes::pricing::{batch, blackscholes, combine, mc};
use cloudshapes::util::json::{obj, Json};
use cloudshapes::workload::{generate, GeneratorConfig, Payoff};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let runs = if smoke { 1 } else { 5 };
    let specs = if smoke { small_cluster() } else { paper_cluster() };
    let sim = SimConfig { stats_cap: 2048, ..SimConfig::exact() }; // noise-free
    let cluster = Cluster::simulated(&specs, &sim, 42).unwrap();
    let workload = if smoke {
        generate(&GeneratorConfig::small(16, 0.02, 7))
    } else {
        generate(&GeneratorConfig::default()) // the 128-task paper workload
    };
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);
    let chunk_sims = if smoke { 1 << 15 } else { 1 << 22 };

    let static_cfg = ExecutorConfig::default();
    let chunked_cfg = ExecutorConfig {
        chunk_sims,
        rebalance: RebalanceConfig { enabled: false, ..Default::default() },
        ..Default::default()
    };
    let rebalance_cfg = ExecutorConfig {
        rebalance: RebalanceConfig { enabled: true, ..Default::default() },
        ..chunked_cfg.clone()
    };

    println!(
        "== perf: executor ({} platforms x {} tasks, virtual clock) ==",
        cluster.len(),
        workload.len()
    );
    let rs = execute_static(&cluster, &workload, &alloc, &static_cfg).unwrap();
    let rc = execute(&cluster, &workload, &alloc, &chunked_cfg).unwrap();
    // Regression gate: the chunked scheduler must reproduce the one-shot
    // report under a noise-free simulator.
    assert_eq!(rc.failures, 0);
    assert!(
        (rs.makespan_secs - rc.makespan_secs).abs() < 1e-9 * rs.makespan_secs.max(1.0),
        "chunked makespan {} drifted from static {}",
        rc.makespan_secs,
        rs.makespan_secs
    );
    let wall_static = common::measure("execute: static (one-shot slices)", runs, || {
        let rep = execute_static(&cluster, &workload, &alloc, &static_cfg).unwrap();
        assert_eq!(rep.failures, 0);
    });
    let wall_chunked = common::measure("execute: chunked event loop", runs, || {
        let rep = execute(&cluster, &workload, &alloc, &chunked_cfg).unwrap();
        assert_eq!(rep.failures, 0);
    });
    let wall_rebalance = common::measure("execute: chunked + rebalance checks", runs, || {
        let rep = execute(&cluster, &workload, &alloc, &rebalance_cfg).unwrap();
        assert_eq!(rep.failures, 0);
    });
    println!(
        "        -> {} slices as {} chunks, {:.0} chunks/s",
        rs.chunks,
        rc.chunks,
        rc.chunks as f64 / wall_chunked
    );

    // Telemetry overhead gate: the same chunked run with every profiling
    // hook live (per-chunk latency + model-error histograms into an enabled
    // registry) must stay within 2% of the bare event loop, modulo a small
    // absolute floor for timer noise. Runs in --smoke too, so CI enforces
    // the budget on every push.
    println!("\n== perf: telemetry overhead gate ==");
    let gate_runs = runs.max(3);
    let wall_base = common::measure("execute: chunked, hooks off", gate_runs, || {
        let rep = execute(&cluster, &workload, &alloc, &chunked_cfg).unwrap();
        assert_eq!(rep.failures, 0);
    });
    let reg = Arc::new(MetricsRegistry::default());
    let wall_instr = common::measure("execute: chunked, hooks on", gate_runs, || {
        let hooks = &mut |ev: &ExecEvent| obs::record_exec_event(&reg, Some(&models), ev);
        let rep = execute_with(&cluster, &workload, &alloc, &chunked_cfg, Some(&models), hooks)
            .unwrap();
        assert_eq!(rep.failures, 0);
    });
    let overhead_pct = (wall_instr / wall_base - 1.0) * 100.0;
    println!("[perf] telemetry overhead: {overhead_pct:+.2}%");
    assert!(
        wall_instr <= wall_base * 1.02 + 0.005,
        "telemetry hooks cost {wall_instr:.4}s vs {wall_base:.4}s bare (> 2% + 5ms)"
    );
    common::save("BENCH_metrics.json", &reg.snapshot(None).to_string_pretty());

    // Straggler recovery: one platform secretly 5x slower than its model —
    // the realised-makespan gap is the executor's adaptivity headline.
    println!("\n== perf: straggler recovery (hidden 5x lane) ==");
    let straggler = specs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.app_gflops.total_cmp(&b.1.app_gflops))
        .map(|(i, _)| i)
        .unwrap();
    let slow_cluster = Cluster::new(
        specs
            .iter()
            .enumerate()
            .map(|(i, s)| -> Arc<dyn Platform> {
                if i == straggler {
                    let seed = 42 + i as u64;
                    Arc::new(SimPlatform::with_hidden_factor(s.clone(), sim.clone(), seed, 5.0))
                } else {
                    Arc::new(SimPlatform::new(s.clone(), sim.clone(), 42 + i as u64))
                }
            })
            .collect(),
    )
    .unwrap();
    let slow_static = execute_static(&slow_cluster, &workload, &alloc, &static_cfg).unwrap();
    let small_chunks = ExecutorConfig { chunk_sims: chunk_sims / 4, ..rebalance_cfg.clone() };
    let slow_rebalanced =
        execute_with(&slow_cluster, &workload, &alloc, &small_chunks, Some(&models), &mut |_| {})
            .unwrap();
    println!(
        "[perf] straggler makespan: static {:.1}s -> rebalanced {:.1}s \
         ({} migrations, {:.0}% of static)",
        slow_static.makespan_secs,
        slow_rebalanced.makespan_secs,
        slow_rebalanced.migrations,
        100.0 * slow_rebalanced.makespan_secs / slow_static.makespan_secs
    );

    // MC kernel throughput gate: scalar oracle vs the batched
    // (vectorisation-ready) kernel, per payoff family. One bit-parity
    // check guards the measurement (mismatch = the numbers are about
    // different computations); the smoke gate enforces batched >= scalar
    // on European in CI, and the full bench targets the 1.5x headline.
    println!("\n== perf: MC kernel — scalar vs batched ({} lanes) ==", batch::LANES);
    let task = workload
        .tasks
        .iter()
        .find(|t| t.payoff == Payoff::European)
        .expect("european task")
        .clone();
    let mut asian = task.clone();
    asian.payoff = Payoff::Asian;
    asian.steps = 64;
    let mut barrier = task.clone();
    barrier.payoff = Payoff::Barrier;
    barrier.barrier = task.spot * 1.4;
    barrier.steps = 64;
    let mut amer = task.clone();
    amer.payoff = Payoff::American;
    amer.strike = task.spot * 1.1; // ITM put: a real early-exercise region
    amer.steps = 32;
    let mut basket = task.clone();
    basket.payoff = Payoff::Basket;
    basket.assets = 4;
    basket.correlation = 0.5;
    basket.steps = 16;
    let mut heston = task.clone();
    heston.payoff = Payoff::Heston;
    heston.correlation = -0.7;
    heston.steps = 64;

    // Oracle gates (run in --smoke too): every exotic family must agree
    // with its independent oracle before its throughput number is
    // published — a fast kernel pricing the wrong thing is not a result.
    println!("\n== perf: exotic-kernel oracle gates ==");
    let gate_n = if smoke { 1u32 << 13 } else { 1 << 15 };
    let est = combine(&mc::simulate(&amer, 42, 0, gate_n), amer.discount());
    let crr = blackscholes::american_put_binomial(
        amer.spot, amer.strike, amer.rate, amer.sigma, amer.maturity, 1000,
    );
    assert!(
        (est.price - crr).abs() < 4.0 * est.std_error + 0.1 * crr,
        "lsmc gate: {est:?} vs binomial {crr}"
    );
    println!("        lsmc vs binomial: {:.4} ± {:.4} vs {crr:.4}", est.price, est.std_error);
    let est = combine(&mc::simulate(&basket, 42, 0, gate_n), basket.discount());
    let mm = blackscholes::basket_call_moment_matched(
        basket.spot, basket.strike, basket.rate, basket.sigma, basket.maturity,
        basket.assets, basket.correlation,
    );
    assert!(
        (est.price - mm).abs() < 4.0 * est.std_error + 0.03 * mm,
        "basket gate: {est:?} vs moment-matched {mm}"
    );
    println!("        basket vs moment-matched: {:.4} ± {:.4} vs {mm:.4}", est.price, est.std_error);
    let mut degenerate = heston.clone();
    degenerate.xi = 0.0;
    degenerate.v0 = degenerate.theta;
    let est = combine(&mc::simulate(&degenerate, 42, 0, gate_n), degenerate.discount());
    let bs = blackscholes::call(
        degenerate.spot, degenerate.strike, degenerate.rate,
        degenerate.theta.sqrt(), degenerate.maturity,
    );
    assert!(
        (est.price - bs).abs() < 4.0 * est.std_error + 0.05,
        "heston gate: {est:?} vs bs(sqrt theta) {bs}"
    );
    println!("        heston(xi=0) vs black-scholes: {:.4} ± {:.4} vs {bs:.4}", est.price, est.std_error);

    let kernel_runs = runs.max(3);
    let mut kernel_rows: Vec<(&str, Json)> = vec![
        ("smoke", Json::Bool(smoke)),
        ("lanes", batch::LANES.into()),
    ];
    let mut euro_speedup = 0.0;
    // Exotic rows: LSMC re-fits its pilot policy inside every simulate()
    // call, so its paths/s includes the regression — the per-chunk cost the
    // per-family latency models see. American has no lane formulation
    // (cross-path regression); its "batched" column is the scalar route.
    for (family, t, n) in [
        ("european", &task, if smoke { 1u32 << 18 } else { 1 << 22 }),
        ("asian64", &asian, if smoke { 1 << 12 } else { 1 << 16 }),
        ("barrier64", &barrier, if smoke { 1 << 12 } else { 1 << 16 }),
        ("lsmc32", &amer, if smoke { 1 << 11 } else { 1 << 14 }),
        ("basket4x16", &basket, if smoke { 1 << 12 } else { 1 << 15 }),
        ("heston64", &heston, if smoke { 1 << 11 } else { 1 << 14 }),
    ] {
        assert_eq!(
            mc::simulate(t, 1, 0, 4099), // odd n: the ragged tail too
            batch::simulate_batch(t, 1, 0, 4099),
            "{family}: batched kernel drifted from the scalar oracle"
        );
        let med_s = common::measure(&format!("{family}: scalar {n} paths"), kernel_runs, || {
            mc::simulate(t, 1, 0, n);
        });
        let med_b = common::measure(&format!("{family}: batched {n} paths"), kernel_runs, || {
            batch::simulate_batch(t, 1, 0, n);
        });
        let (scalar_mps, batched_mps) = (n as f64 / med_s / 1e6, n as f64 / med_b / 1e6);
        let speedup = med_s / med_b;
        println!(
            "        -> {family}: scalar {scalar_mps:.1} Mpaths/s, \
             batched {batched_mps:.1} Mpaths/s ({speedup:.2}x)"
        );
        if family == "european" {
            euro_speedup = speedup;
        }
        kernel_rows.push((family, obj(vec![
            ("paths", (n as usize).into()),
            ("scalar_mpaths_per_s", scalar_mps.into()),
            ("batched_mpaths_per_s", batched_mps.into()),
            ("speedup", speedup.into()),
        ])));
    }
    if smoke {
        // CI sizes are too small for a stable 1.5x bar; regressing below
        // the scalar oracle is the hard failure.
        assert!(
            euro_speedup >= 1.0,
            "batched European kernel slower than scalar ({euro_speedup:.2}x) — \
             the batch formulation stopped vectorising"
        );
    } else if euro_speedup < 1.5 {
        println!(
            "[perf] WARNING: batched European speedup {euro_speedup:.2}x is below \
             the 1.5x bench-size target"
        );
    }
    common::save("BENCH_kernel.json", &obj(kernel_rows).to_string_pretty());

    // Re-measure the scalar mirror at the legacy sizes so the
    // BENCH_executor.json throughput trajectory stays comparable across
    // PRs (the batched numbers live in BENCH_kernel.json).
    let n = 1 << 20;
    let med = common::measure(&format!("simulate {n} european paths"), runs, || {
        mc::simulate(&task, 1, 0, n);
    });
    let n_asian = 1 << 14;
    let med_asian = common::measure(&format!("simulate {n_asian} asian-64 paths"), runs, || {
        mc::simulate(&asian, 1, 0, n_asian);
    });

    let json = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("platforms", cluster.len().into()),
        ("tasks", workload.len().into()),
        ("slices", rs.chunks.into()),
        ("chunks", rc.chunks.into()),
        ("static_wall_s", wall_static.into()),
        ("chunked_wall_s", wall_chunked.into()),
        ("rebalance_wall_s", wall_rebalance.into()),
        ("makespan_s", rs.makespan_secs.into()),
        ("telemetry_base_wall_s", wall_base.into()),
        ("telemetry_instrumented_wall_s", wall_instr.into()),
        ("telemetry_overhead_pct", overhead_pct.into()),
        ("straggler_static_makespan_s", slow_static.makespan_secs.into()),
        ("straggler_rebalanced_makespan_s", slow_rebalanced.makespan_secs.into()),
        ("straggler_migrations", slow_rebalanced.migrations.into()),
        ("mc_european_mpaths_per_s", (n as f64 / med / 1e6).into()),
        (
            "mc_asian64_mpath_steps_per_s",
            (n_asian as f64 * 64.0 / med_asian / 1e6).into(),
        ),
    ]);
    common::save("BENCH_executor.json", &json.to_string_pretty());
    println!("perf_executor bench OK");
}
