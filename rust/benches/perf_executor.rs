//! Perf: cluster executor + benchmarker throughput (virtual-clock dispatch),
//! and the native-mirror Monte Carlo kernel's paths/second.

mod common;

use cloudshapes::coordinator::executor::{execute, ExecutorConfig};
use cloudshapes::coordinator::{benchmark, BenchmarkConfig, HeuristicPartitioner, ModelSet};
use cloudshapes::platforms::spec::paper_cluster;
use cloudshapes::platforms::{Cluster, SimConfig};
use cloudshapes::pricing::mc;
use cloudshapes::workload::{generate, GeneratorConfig, Payoff};

fn main() {
    let specs = paper_cluster();
    let cfg = SimConfig { stats_cap: 2048, ..SimConfig::default() };
    let cluster = Cluster::simulated(&specs, &cfg, 42);
    let workload = generate(&GeneratorConfig::default());
    let models = ModelSet::from_specs(&specs, &workload);
    let alloc = HeuristicPartitioner::upper_bound_allocation(&models);

    println!("== perf: executor (16 platforms x 128 tasks, virtual clock) ==");
    let med = common::measure("execute full allocation", 5, || {
        let rep = execute(&cluster, &workload, &alloc, &ExecutorConfig::default()).unwrap();
        assert_eq!(rep.failures, 0);
    });
    let slices: usize = (0..workload.len())
        .map(|j| (0..cluster.len()).filter(|&i| alloc.get(i, j) > 1e-6).count())
        .sum();
    println!("        -> {slices} slices, {:.0} slices/s", slices as f64 / med);

    println!("\n== perf: benchmarker (16x128 ladder) ==");
    common::measure("benchmark full cluster", 3, || {
        benchmark(&cluster, &workload, &BenchmarkConfig::default());
    });

    println!("\n== perf: native Threefry MC mirror ==");
    let task = workload
        .tasks
        .iter()
        .find(|t| t.payoff == Payoff::European)
        .expect("european task")
        .clone();
    let n = 1 << 20;
    let med = common::measure(&format!("simulate {n} european paths"), 5, || {
        mc::simulate(&task, 1, 0, n);
    });
    println!("        -> {:.1} Mpaths/s", n as f64 / med / 1e6);

    let mut asian = task.clone();
    asian.payoff = Payoff::Asian;
    asian.steps = 64;
    let n = 1 << 14;
    let med = common::measure(&format!("simulate {n} asian-64 paths"), 5, || {
        mc::simulate(&asian, 1, 0, n);
    });
    println!(
        "        -> {:.1} Mpath-steps/s",
        n as f64 * 64.0 / med / 1e6
    );
    println!("perf_executor bench OK");
}
