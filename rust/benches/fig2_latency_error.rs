//! Regenerates **Figure 2**: latency-model prediction error vs problem
//! scale. The paper's claim: relative error within ~10% for problems many
//! times the size of the benchmarking subset.

mod common;

use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::{self, Experiment};
use cloudshapes::util::stats::percentile;

fn main() {
    let (e, _) = common::timed("build paper experiment", || {
        Experiment::build(ExperimentConfig::default()).expect("experiment")
    });
    let multiples = [1.0, 2.0, 5.0, 10.0, 20.0, 50.0];
    let ((plot, points), _) =
        common::timed("fig2 (predict vs fresh executions)", || report::fig2(&e, &multiples));
    let rendered = plot.render();
    println!("\n{rendered}");
    common::save("fig2.txt", &rendered);
    common::save("fig2.csv", &plot.to_csv());

    // Error statistics per scale multiple.
    println!("{:>8} {:>8} {:>10} {:>10}", "scale", "points", "median", "p90");
    for m in multiples {
        let errs: Vec<f64> = points
            .iter()
            .filter(|p| (p.scale - m).abs() < 1e-9)
            .map(|p| p.rel_error)
            .collect();
        if errs.is_empty() {
            continue;
        }
        println!(
            "{m:>8.0} {:>8} {:>9.1}% {:>9.1}%",
            errs.len(),
            percentile(&errs, 50.0) * 100.0,
            percentile(&errs, 90.0) * 100.0
        );
    }
    let all: Vec<f64> = points.iter().map(|p| p.rel_error).collect();
    let median = percentile(&all, 50.0);
    println!("overall median error: {:.1}% (paper: within 10%)", median * 100.0);
    assert!(median < 0.10, "median prediction error {median}");
    println!("fig2 bench OK");
}
