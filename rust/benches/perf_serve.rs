//! Perf: the serve plane under a connection soak. Opens up to 10k
//! concurrent connections (bounded by RLIMIT_NOFILE — the bench raises the
//! soft cap to the hard cap first), drives a pipelined ping/evaluate/batch
//! mix over both framings (half the connections negotiate `lp1`), and
//! gates on:
//!
//! - zero lost responses (every request answered on its connection, in
//!   order),
//! - zero corrupted responses (every line parses and has its op's shape —
//!   an `overload` shed is a *valid* response, counted separately),
//! - a shed-rate bound and a generous P99 accept-to-response bound.
//!
//! Emits `results/BENCH_serve.json`. Pass `--smoke` (the CI mode) for a
//! 512-connection soak; `--canary` seeds one corrupted response copy into
//! the checker and must therefore FAIL — CI asserts the nonzero exit, so a
//! checker that rots into a no-op is caught.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cloudshapes::api::SessionBuilder;
use cloudshapes::cli::serve::serve_until_shutdown;
use cloudshapes::config::ExperimentConfig;
use cloudshapes::coordinator::partitioner::MilpConfig;
use cloudshapes::platforms::sim::SimConfig;
use cloudshapes::serve::{lp1_frame, lp1_read, ServeConfig};
use cloudshapes::util::json::{obj, Json};

/// Raise RLIMIT_NOFILE's soft cap to its hard cap; returns the resulting
/// soft cap. The syscalls are declared directly (no libc crate, per the
/// repo's no-deps idiom).
#[cfg(unix)]
fn raise_and_get_nofile() -> usize {
    use std::os::raw::c_int;
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: c_int, rlim: *mut RLimit) -> c_int;
        fn setrlimit(resource: c_int, rlim: *const RLimit) -> c_int;
    }
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: c_int = 7;
    #[cfg(not(target_os = "linux"))]
    const RLIMIT_NOFILE: c_int = 8;

    let mut lim = RLimit { cur: 1024, max: 1024 };
    // SAFETY: plain struct-out syscalls on the current process.
    unsafe {
        if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
            return 1024;
        }
        if lim.cur < lim.max {
            let want = RLimit { cur: lim.max, max: lim.max };
            if setrlimit(RLIMIT_NOFILE, &want) == 0 {
                lim.cur = lim.max;
            }
        }
    }
    lim.cur.min(1 << 20) as usize
}

#[cfg(not(unix))]
fn raise_and_get_nofile() -> usize {
    1024
}

/// The request mix, one per (connection, round). Cached solves: the cache
/// is prewarmed, so the soak measures the serve plane, not the solver.
fn request_for(conn: usize, round: usize) -> (&'static str, &'static str) {
    match (conn + round) % 3 {
        0 => ("ping", r#""op":"ping""#),
        1 => ("evaluate", r#""op":"evaluate","partitioner":"heuristic","budget":null"#),
        _ => ("batch", r#""op":"batch","partitioner":"heuristic","budgets":[null,1000000.0]"#),
    }
}

/// Classify one response line: `Ok(true)` = valid success, `Ok(false)` =
/// valid overload shed, `Err` = corrupted.
fn check_response(op: &str, line: &str) -> Result<bool, String> {
    let json = Json::parse(line).map_err(|e| format!("{op}: unparseable ({e}): {line}"))?;
    if json.get("v").and_then(Json::as_u64) != Some(1) {
        return Err(format!("{op}: missing v:1: {line}"));
    }
    if let Some(err) = json.get("error") {
        return match err.get("kind").and_then(Json::as_str) {
            Some("overload") => Ok(false),
            other => Err(format!("{op}: unexpected error kind {other:?}: {line}")),
        };
    }
    let shaped = match op {
        "ping" => json.get("pong") == Some(&Json::Bool(true)),
        "evaluate" => json.get("predicted_latency_s").is_some(),
        "batch" => json.get("results").is_some(),
        _ => false,
    };
    if !shaped {
        return Err(format!("{op}: malformed success payload: {line}"));
    }
    Ok(true)
}

struct ThreadReport {
    /// (op, response line) per request, in issue order.
    responses: Vec<(&'static str, String)>,
    /// Seconds from write to read-back per request.
    latencies: Vec<f64>,
    lost: usize,
}

/// Drive `conns` connections for `rounds` rounds: each round writes one
/// request on every connection (pipelining across the fleet), then reads
/// every response back in order. Odd-indexed connections negotiate lp1 on
/// their first request.
fn drive(
    addr: std::net::SocketAddr,
    first_conn: usize,
    conns: usize,
    rounds: usize,
) -> ThreadReport {
    let mut sockets = Vec::with_capacity(conns);
    for i in 0..conns {
        let mut attempts = 0;
        let stream = loop {
            match TcpStream::connect(addr) {
                Ok(s) => break s,
                Err(e) => {
                    attempts += 1;
                    assert!(attempts < 50, "connect {}/{conns} failed: {e}", i + 1);
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        };
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        stream.set_nodelay(true).unwrap();
        let lp1 = (first_conn + i) % 2 == 1;
        sockets.push((stream, lp1, false)); // (socket, wants_lp1, negotiated)
    }

    let mut report = ThreadReport {
        responses: Vec::with_capacity(conns * rounds),
        latencies: Vec::with_capacity(conns * rounds),
        lost: 0,
    };
    let mut readers: Vec<BufReader<TcpStream>> =
        sockets.iter().map(|(s, _, _)| BufReader::new(s.try_clone().unwrap())).collect();

    for round in 0..rounds {
        let mut sent: Vec<(&'static str, Instant)> = Vec::with_capacity(conns);
        for (i, (stream, wants_lp1, negotiated)) in sockets.iter_mut().enumerate() {
            let (op, body) = request_for(first_conn + i, round);
            let negotiate = *wants_lp1 && !*negotiated;
            let framing = if negotiate { r#","framing":"lp1""# } else { "" };
            let line = format!("{{\"v\":1,{body}{framing}}}");
            let wire = if *wants_lp1 && *negotiated {
                lp1_frame(&line)
            } else {
                format!("{line}\n").into_bytes()
            };
            let t = Instant::now();
            if stream.write_all(&wire).is_err() {
                report.lost += 1;
                sent.push(("", t));
                continue;
            }
            if negotiate {
                *negotiated = true;
            }
            sent.push((op, t));
        }
        for (i, &(op, started)) in sent.iter().enumerate() {
            if op.is_empty() {
                continue; // write already counted as lost
            }
            let lp1 = sockets[i].1;
            let line = if lp1 {
                lp1_read(&mut readers[i]).unwrap_or_default()
            } else {
                let mut buf = String::new();
                match readers[i].read_line(&mut buf) {
                    Ok(n) if n > 0 => {}
                    _ => buf.clear(),
                }
                buf.trim().to_string()
            };
            if line.is_empty() {
                report.lost += 1;
                continue;
            }
            report.latencies.push(started.elapsed().as_secs_f64());
            report.responses.push((op, line));
        }
    }
    report
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let canary = args.iter().any(|a| a == "--canary");
    if cfg!(not(unix)) {
        println!("perf_serve: serve plane requires unix; skipping");
        return;
    }

    let nofile = raise_and_get_nofile();
    // One client fd + one server fd per connection, both in this process;
    // leave headroom for the session's own threads and files.
    let fd_cap = nofile.saturating_sub(256) / 2;
    let target = if smoke { 512 } else { 10_000 };
    let connections = target.min(fd_cap).max(16);
    let rounds = 3;
    let threads = if smoke { 8 } else { 16 };

    println!(
        "== perf: serve plane soak ({connections} connections x {rounds} rounds, \
         nofile {nofile}) =="
    );

    // Noise-free session so repeated solves are cache hits with
    // byte-identical payloads; an in-flight budget sized for the fleet.
    let serve_cfg = ServeConfig { max_inflight: 4096, ..ServeConfig::default() };
    let mut cluster = ExperimentConfig::quick().cluster;
    cluster.sim = SimConfig::exact();
    let (session, build_secs) = common::timed("session build (benchmark + models)", || {
        SessionBuilder::quick()
            .cluster(cluster)
            .milp(MilpConfig { time_limit_secs: 2.0, ..Default::default() })
            .budget_sweep(3)
            .serve(serve_cfg)
            .build()
            .unwrap()
    });
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let session = Arc::new(session);
    let server = std::thread::spawn(move || serve_until_shutdown(listener, session));

    // Prewarm the cache so the soak exercises the serve plane, not the
    // solver: one connection issues each solve in the mix once.
    for round in 0..3 {
        let (op, body) = request_for(0, round);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(format!("{{\"v\":1,{body}}}\n").as_bytes()).unwrap();
        let mut line = String::new();
        BufReader::new(s).read_line(&mut line).unwrap();
        check_response(op, line.trim()).unwrap_or_else(|e| panic!("prewarm {e}"));
    }

    let (reports, soak_secs) = common::timed("soak", || {
        let per = connections / threads;
        let extra = connections % threads;
        let mut first = 0usize;
        let handles: Vec<_> = (0..threads)
            .map(|t| {
                let count = per + usize::from(t < extra);
                let start = first;
                first += count;
                std::thread::spawn(move || drive(addr, start, count, rounds))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect::<Vec<ThreadReport>>()
    });

    let mut responses: Vec<(&'static str, String)> = Vec::new();
    let mut latencies: Vec<f64> = Vec::new();
    let mut lost = 0usize;
    for mut r in reports {
        responses.append(&mut r.responses);
        latencies.append(&mut r.latencies);
        lost += r.lost;
    }

    if canary {
        // Deterministically corrupt one response before verification; the
        // checker MUST flag it (CI asserts this run exits nonzero).
        let idx = 0xC0FFEE % responses.len().max(1);
        println!("[canary] corrupting response #{idx}");
        responses[idx].1 = responses[idx].1.replace(':', ";");
    }

    let mut shed = 0usize;
    let mut corrupted: Vec<String> = Vec::new();
    for (op, line) in &responses {
        match check_response(op, line) {
            Ok(true) => {}
            Ok(false) => shed += 1,
            Err(e) => corrupted.push(e),
        }
    }

    let total = connections * rounds;
    let answered = responses.len();
    let shed_rate = shed as f64 / answered.max(1) as f64;
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        latencies[((latencies.len() - 1) as f64 * p) as usize]
    };
    let (p50, p99) = (pct(0.50), pct(0.99));

    println!(
        "[perf] serve soak: {answered}/{total} answered, {lost} lost, {} corrupted, \
         {shed} shed ({:.2}%), p50 {:.1}ms, p99 {:.1}ms",
        corrupted.len(),
        shed_rate * 100.0,
        p50 * 1e3,
        p99 * 1e3
    );

    common::save(
        "BENCH_serve.json",
        &obj(vec![
            ("bench", "serve_soak".into()),
            ("smoke", Json::Bool(smoke)),
            ("connections", connections.into()),
            ("rounds", rounds.into()),
            ("requests", total.into()),
            ("answered", answered.into()),
            ("lost", lost.into()),
            ("corrupted", corrupted.len().into()),
            ("shed", shed.into()),
            ("shed_rate", shed_rate.into()),
            ("p50_secs", p50.into()),
            ("p99_secs", p99.into()),
            ("session_build_secs", build_secs.into()),
            ("soak_secs", soak_secs.into()),
        ])
        .to_string_pretty(),
    );

    // Shut the plane down cleanly before judging the gates, so a gate
    // failure doesn't leak the server thread into the panic backtrace.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(b"{\"v\":1,\"op\":\"shutdown\"}\n").unwrap();
    let mut line = String::new();
    BufReader::new(s).read_line(&mut line).unwrap();
    server.join().unwrap().unwrap();

    // Gates.
    for c in corrupted.iter().take(5) {
        eprintln!("[gate] corrupted: {c}");
    }
    assert!(corrupted.is_empty(), "{} corrupted responses", corrupted.len());
    assert_eq!(lost, 0, "lost {lost} responses");
    assert_eq!(answered, total, "answered {answered} of {total}");
    assert!(shed_rate <= 0.05, "shed rate {shed_rate:.3} exceeds the 5% bound");
    assert!(p99 <= 10.0, "p99 {p99:.2}s exceeds the 10s bound");
    println!("[gate] serve soak: all gates passed");
}
