//! Regenerates **Table II**: the 16-platform experimental cluster with
//! *measured* application performance — the benchmarking procedure runs on
//! the simulated testbed and the achieved GFLOPS column is derived from the
//! fitted β, exactly how the paper measures application performance.

mod common;

use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::{self, Experiment};

fn main() {
    let (e, _) = common::timed("build paper experiment (benchmark 16x128)", || {
        Experiment::build(ExperimentConfig::default()).expect("experiment")
    });
    let table = report::tables::table2_for(&e);
    let rendered = table.render();
    println!("\n{rendered}");
    common::save("table2.txt", &rendered);
    common::save("table2.csv", &table.to_csv());

    assert_eq!(table.n_rows(), 16, "Table II lists 16 platforms");
    for needle in ["virtex6#0", "stratix5-gsd8#7", "gk104", "xeon-e5-2660", "xeon-gce"] {
        assert!(rendered.contains(needle), "missing {needle}");
    }
    // Measured GFLOPS should be within the simulator's hidden spread (±12%)
    // + noise of the spec value for the heavyweight platforms.
    let m = e.models();
    // Largest task: work-dominated, so β (hence achieved GFLOPS) is well
    // identified — same choice the table itself renders.
    let j = (0..e.workload.len())
        .max_by(|&a, &b| {
            e.workload.tasks[a]
                .total_flops()
                .partial_cmp(&e.workload.tasks[b].total_flops())
                .unwrap()
        })
        .unwrap();
    for (i, spec) in e.cluster.specs().iter().enumerate() {
        let measured = e.workload.tasks[j].flops_per_path() / m.model(i, j).beta / 1e9;
        let ratio = measured / spec.app_gflops;
        assert!(
            (0.7..1.4).contains(&ratio),
            "{}: measured/spec GFLOPS ratio {ratio}",
            spec.name
        );
    }
    println!("table2 bench OK");
}
