//! Shared scaffolding for the bench binaries (`harness = false`; criterion
//! is unavailable offline). Each bench regenerates one paper table/figure
//! and reports wall-clock timings; outputs also land in `results/`.

use std::time::Instant;

/// Run `f`, print and return its duration in seconds.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> (T, f64) {
    let start = Instant::now();
    let out = f();
    let secs = start.elapsed().as_secs_f64();
    println!("[bench] {label}: {secs:.2}s");
    (out, secs)
}

/// Write an artifact into `results/` (best-effort; benches still print to
/// stdout).
pub fn save(name: &str, contents: &str) {
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{name}");
    match std::fs::write(&path, contents) {
        Ok(()) => println!("[bench] wrote {path}"),
        Err(e) => eprintln!("[bench] could not write {path}: {e}"),
    }
}

/// Median-of-runs micro timing for the perf_* benches.
pub fn measure(label: &str, runs: usize, mut f: impl FnMut()) -> f64 {
    assert!(runs > 0);
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    println!(
        "[perf] {label}: median {:.4}s (min {:.4}s, max {:.4}s, {} runs)",
        median,
        times[0],
        times[times.len() - 1],
        runs
    );
    median
}
