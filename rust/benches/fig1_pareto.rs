//! Regenerates **Figure 1**: the latency-vs-cost Pareto trade-off for the
//! 128-task workload on the 16-platform heterogeneous cluster.

mod common;

use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::{self, Experiment};

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sweep.levels = 9;
    let (e, _) = common::timed("build paper experiment", || {
        Experiment::build(cfg).expect("experiment")
    });
    let ((plot, curve), _) = common::timed("fig1 sweep (9 MILP solves)", || {
        report::fig1(&e).expect("fig1")
    });
    let rendered = plot.render();
    println!("\n{rendered}");
    common::save("fig1.txt", &rendered);
    common::save("fig1.csv", &plot.to_csv());

    // The trade-off must be real: meaningfully cheaper at the cheap end,
    // meaningfully faster at the fast end.
    let front = curve.pareto_front();
    assert!(front.len() >= 3, "degenerate front: {} points", front.len());
    let cheap = front.first().unwrap();
    let fast = front.last().unwrap();
    println!(
        "front: ${:.2}/{:.0}s ... ${:.2}/{:.0}s ({} points)",
        cheap.cost, cheap.latency, fast.cost, fast.latency, front.len()
    );
    assert!(fast.cost > 1.5 * cheap.cost, "cost range too flat");
    assert!(cheap.latency > 1.5 * fast.latency, "latency range too flat");
    // Monotone front.
    for w in front.windows(2) {
        assert!(w[0].cost <= w[1].cost && w[0].latency >= w[1].latency);
    }
    println!("fig1 bench OK");
}
