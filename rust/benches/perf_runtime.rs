//! Perf: the PJRT request path — compile time per variant, chunk execution
//! latency, and end-to-end pricing throughput (paths/second) per payoff
//! family. This is the L1/L2 hot path as seen from rust; the structural
//! VMEM/roofline analysis is in EXPERIMENTS.md §Perf.

mod common;

use std::path::PathBuf;

use cloudshapes::runtime::EngineHandle;
use cloudshapes::workload::option::{OptionTask, Payoff};

fn task(payoff: Payoff) -> OptionTask {
    OptionTask {
        id: 9,
        payoff,
        spot: 100.0,
        strike: 105.0,
        rate: 0.05,
        sigma: 0.2,
        maturity: 1.0,
        barrier: 140.0,
        steps: 64,
        target_accuracy: 0.01,
        n_sims: 1 << 20,
        ..OptionTask::default()
    }
}

fn main() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = match EngineHandle::spawn(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("perf_runtime skipped: {e:#} (run `make artifacts`)");
            return;
        }
    };
    println!("platform: {}", engine.platform_name());

    println!("\n== compile (all variants) ==");
    common::measure("warmup/compile", 1, || engine.warmup().unwrap());

    println!("\n== chunk pricing throughput ==");
    for (payoff, n) in [
        (Payoff::European, 1u64 << 20),
        (Payoff::Asian, 1 << 16),
        (Payoff::Barrier, 1 << 16),
    ] {
        let t = task(payoff);
        let med = common::measure(&format!("{} x{}", payoff.name(), n), 5, || {
            let stats = engine.price(&t, n, 3).unwrap();
            assert!(stats.n >= n);
        });
        let steps = if payoff == Payoff::European { 1 } else { 64 };
        println!(
            "        -> {:.2} Mpaths/s ({:.1} Mpath-steps/s)",
            n as f64 / med / 1e6,
            n as f64 * steps as f64 / med / 1e6
        );
    }

    println!("\n== single smallest-chunk latency (dispatch overhead) ==");
    let t = task(Payoff::European);
    let med = common::measure("price 1 path (forces 4096-chunk)", 10, || {
        engine.price(&t, 1, 5).unwrap();
    });
    println!("        -> {:.3} ms/dispatch", med * 1e3);
    println!("perf_runtime bench OK");
}
