//! Perf: the market-storm scheduling path — a seeded tick stream of
//! correlated burst re-prices driven into the online scheduler twice, once
//! with predictive autoscaling (forecaster-driven pre-rent/drain) and once
//! with the rent-everything baseline. Emits `results/BENCH_storm.json` so
//! the perf trajectory accumulates data across PRs.
//!
//! Gates (the CI regression contract, `--smoke` shrinks the stream):
//!   - every job in the forecasted run meets its P99 deadline SLO,
//!   - no job is lost (failed/cancelled/shed) in either run,
//!   - the forecasted run bills strictly less than the baseline (idle
//!     rentals included),
//!   - the incremental re-plan path (delta-admit + plan memo) is at least
//!     as fast per plan as the cold full solve it replaces.
//!
//! Everything executes on the simulated cluster in cluster-virtual time, so
//! the stream is deterministic and the bench runs in wall-clock seconds
//! while modelling >1M Monte Carlo path re-prices.

mod common;

use std::time::{Duration, Instant};

use cloudshapes::coordinator::{
    ExecutorConfig, HeuristicPartitioner, JobState, OnlineScheduler, SchedulerConfig,
    SchedulerStats,
};
use cloudshapes::models::{ForecastConfig, MarketSim, PlatformPrior, StormConfig};
use cloudshapes::platforms::{Catalogue, Cluster, SimConfig};
use cloudshapes::util::json::{obj, Json};

/// One scheduler run over the full tick stream.
struct VariantOut {
    p99_s: f64,
    max_latency_s: f64,
    billed: f64,
    job_cost: f64,
    idle_cost: f64,
    shed: usize,
    stats: SchedulerStats,
    wall_s: f64,
}

fn p99(latencies: &mut [f64]) -> f64 {
    assert!(!latencies.is_empty(), "no completed jobs to take a P99 over");
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((latencies.len() as f64 * 0.99).ceil() as usize).clamp(1, latencies.len());
    latencies[idx - 1]
}

/// Drive the whole tick stream through one scheduler instance. Each tick's
/// jobs are submitted together (the correlated burst), then the driver
/// waits for at least one epoch boundary so ticks map ~1:1 onto epochs and
/// the forecaster sees the storm cadence as a periodic arrival series.
fn run_variant(storm: &StormConfig, counts: &[usize], max_in_flight: usize, forecast: bool) -> VariantOut {
    let catalogue = Catalogue::small();
    let specs = catalogue.instantiate(counts, false).expect("storm testbed instantiates");
    let cluster = Cluster::simulated(&specs, &SimConfig::exact(), 21).expect("simulated cluster");
    let priors: Vec<PlatformPrior> = cluster
        .specs()
        .iter()
        .map(|s| PlatformPrior {
            throughput_flops: s.app_gflops.max(1e-9) * 1e9,
            setup_secs: s.setup_secs,
        })
        .collect();
    let cfg = SchedulerConfig {
        enabled: true,
        max_in_flight,
        forecast: ForecastConfig {
            enabled: forecast,
            // One season = one storm period, so the seasonal term can learn
            // the burst cadence and pre-rent ahead of it.
            season_len: storm.storm_every.max(2),
            ..Default::default()
        },
        ..Default::default()
    };
    let sched = OnlineScheduler::start(cluster, priors, ExecutorConfig::default(), cfg, || {
        Ok(Box::new(HeuristicPartitioner::default()))
    })
    .expect("scheduler starts");

    let sim = MarketSim::new(storm.clone()).expect("valid storm config");
    let mut ids = Vec::with_capacity(sim.total_jobs());
    let mut shed = 0usize;
    let label = if forecast { "storm+forecast" } else { "storm baseline" };
    let (_, wall_s) = common::timed(label, || {
        for t in 0..sim.ticks() {
            let tick = sim.tick(t).expect("tick in range");
            let epoch_before = sched.counters().epochs;
            for job in tick.jobs {
                match sched.submit(job) {
                    Ok(id) => ids.push(id),
                    Err(e) if e.kind() == "overload" => shed += 1,
                    Err(e) => panic!("submit failed: {e}"),
                }
            }
            // Pace the stream: let the epoch loop consume this tick's
            // arrivals before the next market move fires. A tick whose last
            // job already drained counts as consumed (the loop can park
            // between ticks, so epoch counters alone would stall here).
            let pace = Instant::now() + Duration::from_secs(20);
            while sched.counters().epochs <= epoch_before && Instant::now() < pace {
                let drained = ids.last().map_or(true, |&id| {
                    sched.job_status(id).map_or(true, |s| s.state.is_terminal())
                });
                if drained {
                    break;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
        // Drain: every submitted job must reach a terminal state.
        let deadline = Instant::now() + Duration::from_secs(300);
        for &id in &ids {
            loop {
                let st = sched.job_status(id).expect("job tracked");
                if st.state.is_terminal() {
                    break;
                }
                assert!(Instant::now() < deadline, "job {id} never drained: {st:?}");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    });

    let mut latencies = Vec::with_capacity(ids.len());
    let mut job_cost = 0.0;
    for &id in &ids {
        let st = sched.job_status(id).expect("job tracked");
        assert_eq!(st.state, JobState::Done, "job {id} not done: {:?}", st.state);
        latencies.push(st.finished_s.expect("terminal jobs are stamped") - st.arrival_s);
        job_cost += st.cost;
    }
    let stats = sched.stats();
    sched.shutdown();
    let p99_s = p99(&mut latencies);
    let max_latency_s = latencies.last().copied().unwrap_or(0.0);
    VariantOut {
        p99_s,
        max_latency_s,
        billed: job_cost + stats.idle_cost,
        job_cost,
        idle_cost: stats.idle_cost,
        shed,
        stats,
        wall_s,
    }
}

fn variant_json(v: &VariantOut) -> Json {
    obj(vec![
        ("p99_latency_s", v.p99_s.into()),
        ("max_latency_s", v.max_latency_s.into()),
        ("billed_cost", v.billed.into()),
        ("job_cost", v.job_cost.into()),
        ("idle_cost", v.idle_cost.into()),
        ("shed", v.shed.into()),
        ("epochs", v.stats.epochs.into()),
        ("full_solves", v.stats.resolves.into()),
        ("replans_incremental", v.stats.replans_incremental.into()),
        ("replans_full", v.stats.replans_full.into()),
        ("memo_hits", v.stats.memo_hits.into()),
        ("warm_reuses", v.stats.warm_reuses.into()),
        ("plan_secs_incremental", v.stats.plan_secs_incremental.into()),
        ("plan_secs_full", v.stats.plan_secs_full.into()),
        ("rented_instances_last", v.stats.rented_instances.into()),
        (
            "forecast_error",
            v.stats.forecast_error.map_or(Json::Null, Json::from),
        ),
        ("wall_s", v.wall_s.into()),
    ])
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    // The simulated trading day: a steady base load with a correlated
    // portfolio-wide re-price storm every `storm_every` ticks.
    let storm = if smoke {
        StormConfig {
            ticks: 12,
            base_jobs: 1,
            storm_every: 4,
            storm_jobs: 8,
            accuracy: 0.2,
            ..Default::default()
        }
    } else {
        StormConfig {
            ticks: 48,
            base_jobs: 2,
            storm_every: 12,
            storm_jobs: 64,
            // Tighter CI target -> bigger N per task -> storms span epochs,
            // which is what exercises delta-admit against surviving work.
            accuracy: 0.05,
            ..Default::default()
        }
    };
    let counts = if smoke { vec![1, 1, 1] } else { vec![2, 2, 2] };
    let max_in_flight = if smoke { 16 } else { 64 };

    let sim = MarketSim::new(storm.clone()).expect("valid storm config");
    let total_sims = sim.total_sims().expect("stream enumerates");
    println!(
        "== perf: market storm ({} ticks, {} jobs, {:.1}M path re-prices, deadline {}s) ==",
        sim.ticks(),
        sim.total_jobs(),
        total_sims as f64 / 1e6,
        storm.deadline_secs
    );
    assert!(total_sims >= 1_000_000, "stream too small to call a storm: {total_sims}");

    // The catalogue's spot markets over the simulated day — the price series
    // that makes shape decisions time-of-day dependent (sampled per tick at
    // the default epoch cadence; offers without spot terms are omitted).
    let catalogue = Catalogue::small();
    let epoch_secs = SchedulerConfig::default().epoch_secs;
    let mut spot_curves = Vec::new();
    for (t, offer) in catalogue.offers().iter().enumerate() {
        let rates: Vec<Json> = (0..sim.ticks())
            .filter_map(|k| {
                catalogue.spot_rate_at(t, k as f64 * epoch_secs, storm.spot_volatility)
            })
            .map(Json::from)
            .collect();
        if !rates.is_empty() {
            spot_curves.push(obj(vec![
                ("offer", offer.spec.name.as_str().into()),
                ("rate_per_hour", Json::Arr(rates)),
            ]));
        }
    }

    let baseline = run_variant(&storm, &counts, max_in_flight, false);
    let forecast = run_variant(&storm, &counts, max_in_flight, true);

    // Per-plan wall-clock: incremental (delta-admit + memo hits are both
    // "cheap path" plans) vs the cold full solve. Pool both runs for a
    // stable average; the baseline exercises the same re-plan machinery.
    let cheap_plans = baseline.stats.replans_incremental + forecast.stats.replans_incremental;
    let cheap_secs = baseline.stats.plan_secs_incremental + forecast.stats.plan_secs_incremental;
    let full_plans = baseline.stats.resolves + forecast.stats.resolves;
    let full_secs = baseline.stats.plan_secs_full + forecast.stats.plan_secs_full;
    assert!(
        forecast.stats.replans_incremental >= 1,
        "the forecasted storm never took the incremental re-plan path"
    );
    assert!(full_plans >= 1, "no full solve ever ran");
    let avg_cheap = cheap_secs / cheap_plans.max(1) as f64;
    let avg_full = full_secs / full_plans as f64;
    let speedup = avg_full / avg_cheap.max(1e-12);
    println!(
        "[perf] re-plan: {} incremental at {:.1}us avg vs {} full at {:.1}us avg ({:.1}x)",
        cheap_plans,
        avg_cheap * 1e6,
        full_plans,
        avg_full * 1e6,
        speedup
    );
    println!(
        "[perf] billed: baseline ${:.3} (idle ${:.3}) vs forecast ${:.3} (idle ${:.3}); \
         P99 {:.0}s vs {:.0}s",
        baseline.billed,
        baseline.idle_cost,
        forecast.billed,
        forecast.idle_cost,
        baseline.p99_s,
        forecast.p99_s
    );

    // Regression gates (see module docs).
    assert!(
        forecast.p99_s <= storm.deadline_secs + 1e-6,
        "P99 {:.0}s misses the {:.0}s deadline SLO",
        forecast.p99_s,
        storm.deadline_secs
    );
    for (name, v) in [("baseline", &baseline), ("forecast", &forecast)] {
        assert_eq!(v.shed, 0, "{name}: storm shed {} jobs", v.shed);
        assert_eq!(
            v.stats.failed + v.stats.cancelled,
            0,
            "{name}: lost jobs (failed {}, cancelled {})",
            v.stats.failed,
            v.stats.cancelled
        );
    }
    assert!(
        forecast.billed < baseline.billed,
        "forecasting did not cut the bill: ${:.3} vs ${:.3}",
        forecast.billed,
        baseline.billed
    );
    assert!(
        speedup >= 1.0,
        "incremental re-plan slower than cold solve: {:.1}us vs {:.1}us",
        avg_cheap * 1e6,
        avg_full * 1e6
    );

    let json = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("ticks", sim.ticks().into()),
        ("jobs", sim.total_jobs().into()),
        ("total_sims", (total_sims as f64).into()),
        ("deadline_s", storm.deadline_secs.into()),
        ("instances", counts.iter().sum::<usize>().into()),
        ("spot_curves", Json::Arr(spot_curves)),
        ("baseline", variant_json(&baseline)),
        ("forecast", variant_json(&forecast)),
        ("replan_speedup", speedup.into()),
        (
            "billed_saving_pct",
            (100.0 * (1.0 - forecast.billed / baseline.billed)).into(),
        ),
    ]);
    common::save("BENCH_storm.json", &json.to_string_pretty());
    println!("perf_storm bench OK");
}
