//! Regenerates **Table I**: comparison of IaaS offerings (provider, instance,
//! time quantum, peak performance, rate). Static published data — the bench
//! verifies the embedded spec DB renders the paper's rows.

mod common;

use cloudshapes::report;

fn main() {
    let (table, _) = common::timed("table1", report::table1);
    let rendered = table.render();
    println!("\n{rendered}");
    common::save("table1.txt", &rendered);
    common::save("table1.csv", &table.to_csv());

    // Paper row spot-checks.
    for needle in ["A4", "n1-highcpu-8", "c3.4xlarge", "g2.2xlarge", "0.650", "0.352"] {
        assert!(rendered.contains(needle), "missing {needle}");
    }
    println!("table1 bench OK");
}
