//! Perf: the MILP stack (simplex node LPs, full partitioner solves, and the
//! 1-vs-N-worker parallel search) — the L3 hot path that dominates
//! Pareto-sweep wall-clock. Baselines + targets live in EXPERIMENTS.md
//! §Perf.
//!
//! Pass `--smoke` (the CI mode) to shrink instance sizes and run counts so
//! the bench acts as a fast solver-regression gate rather than a
//! measurement session.

mod common;

use cloudshapes::coordinator::partitioner::{MilpConfig, MilpPartitioner};
use cloudshapes::coordinator::{HeuristicPartitioner, ModelSet, Partitioner};
use cloudshapes::milp::{self, BnbLimits, Cmp, MilpStatus, Problem};
use cloudshapes::milp::simplex;
use cloudshapes::platforms::spec::paper_cluster;
use cloudshapes::util::rng::Rng;
use cloudshapes::workload::{generate, GeneratorConfig};

fn paper_models() -> ModelSet {
    let specs = paper_cluster();
    let w = generate(&GeneratorConfig::default());
    ModelSet::from_specs(&specs, &w)
}

/// A transportation LP shaped like the reduced partitioning node LP.
fn node_shaped_lp(mu: usize, tau: usize, seed: u64) -> Problem {
    let mut rng = Rng::new(seed);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..mu * tau)
        .map(|k| p.cont(&format!("a{k}"), 0.0, 1.0))
        .collect();
    let f = p.cont("f", 0.0, f64::INFINITY);
    for j in 0..tau {
        let terms: Vec<_> = (0..mu).map(|i| (vars[i * tau + j], 1.0)).collect();
        p.constrain(terms, Cmp::Eq, 1.0);
    }
    for i in 0..mu {
        let mut terms: Vec<_> = (0..tau)
            .map(|j| (vars[i * tau + j], rng.range_f64(1.0, 100.0)))
            .collect();
        terms.push((f, -1.0));
        p.constrain(terms, Cmp::Le, 0.0);
    }
    p.minimize(vec![(f, 1.0)]);
    p
}

/// A knapsack whose tree is deep enough to keep several workers busy.
fn knapsack(n: usize, seed: u64) -> Problem {
    let mut rng = Rng::new(seed);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..n).map(|i| p.bin(&format!("b{i}"))).collect();
    let w: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 9.0)).collect();
    let c: Vec<f64> = (0..n).map(|_| rng.range_f64(-9.0, 4.0)).collect();
    let cap = w.iter().sum::<f64>() * 0.4;
    p.constrain(vars.iter().zip(&w).map(|(b, w)| (*b, *w)).collect(), Cmp::Le, cap);
    p.minimize(vars.iter().zip(&c).map(|(b, c)| (*b, *c)).collect());
    p
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let runs = if smoke { 1 } else { 5 };

    println!("== perf: simplex ==");
    let simplex_cases: &[(usize, usize)] =
        if smoke { &[(4, 16), (8, 64)] } else { &[(4, 16), (8, 64), (16, 128)] };
    for &(mu, tau) in simplex_cases {
        let lp = node_shaped_lp(mu, tau, 7);
        common::measure(&format!("simplex {mu}x{tau} node LP"), runs, || {
            let sol = simplex::solve(&lp);
            assert_eq!(sol.status, cloudshapes::milp::LpStatus::Optimal);
        });
    }

    println!("\n== perf: parallel branch & bound (generic solver, 1 vs 4 workers) ==");
    let kn = knapsack(if smoke { 14 } else { 20 }, 11);
    let mut objs: Vec<f64> = Vec::new();
    for workers in [1usize, 4] {
        let lim = BnbLimits {
            rel_gap: 0.0,
            workers,
            max_nodes: 5_000_000,
            time_limit_secs: 300.0,
        };
        common::measure(&format!("bnb knapsack ({workers} workers)"), runs, || {
            let sol = milp::solve_milp(&kn, &lim);
            assert_eq!(sol.status, MilpStatus::Optimal);
            objs.push(sol.obj);
        });
    }
    // Regression gate: every run, at every worker count, must return the
    // identical objective bits.
    assert!(
        objs.windows(2).all(|w| w[0].to_bits() == w[1].to_bits()),
        "parallel objective drift: {objs:?}"
    );

    println!("\n== perf: partitioners at paper scale (16x128) ==");
    let models = paper_models();
    common::measure("heuristic partition (budgeted sweep)", runs, || {
        let h = HeuristicPartitioner::default();
        h.partition(&models, Some(8.0)).unwrap();
    });
    let node_budgets: &[usize] = if smoke { &[1, 10] } else { &[1, 50, 200] };
    for &nodes in node_budgets {
        let cfg = MilpConfig { max_nodes: nodes, time_limit_secs: 120.0, ..Default::default() };
        let p = MilpPartitioner::new(cfg);
        let mut makespan = 0.0;
        let med =
            common::measure(&format!("milp solve ({nodes} nodes budget)"), runs.min(3), || {
                let out = p.solve(&models, Some(8.0)).unwrap();
                makespan = out.makespan;
            });
        println!("        -> makespan {makespan:.0}s at {med:.2}s solve time");
    }

    println!("\n== perf: milp partitioner 1 vs 4 workers (the 128x16 instance) ==");
    // rel_gap 0 pins both searches to the same full node budget so the
    // comparison measures the parallel node-LP rounds, not early gap exits.
    let mk = |workers| MilpConfig {
        max_nodes: if smoke { 6 } else { 60 },
        rel_gap: 0.0,
        time_limit_secs: 600.0,
        workers,
    };
    let t1 = common::measure("milp partition (1 worker)", 1, || {
        MilpPartitioner::new(mk(1)).solve(&models, Some(8.0)).unwrap();
    });
    let t4 = common::measure("milp partition (4 workers)", 1, || {
        MilpPartitioner::new(mk(4)).solve(&models, Some(8.0)).unwrap();
    });
    println!("        -> multi-worker speedup on 128x16: {:.2}x (1 -> 4 workers)", t1 / t4);

    println!("perf_solver bench OK");
}
