//! Perf: the MILP stack (simplex node LPs, full partitioner solves) — the
//! L3 hot path that dominates Pareto-sweep wall-clock. Baselines + targets
//! live in EXPERIMENTS.md §Perf.

mod common;

use cloudshapes::coordinator::partitioner::{MilpConfig, MilpPartitioner};
use cloudshapes::coordinator::{HeuristicPartitioner, ModelSet, Partitioner};
use cloudshapes::milp::lp::{Cmp, Problem};
use cloudshapes::milp::simplex;
use cloudshapes::platforms::spec::paper_cluster;
use cloudshapes::util::rng::Rng;
use cloudshapes::workload::{generate, GeneratorConfig};

fn paper_models() -> ModelSet {
    let specs = paper_cluster();
    let w = generate(&GeneratorConfig::default());
    ModelSet::from_specs(&specs, &w)
}

/// A transportation LP shaped like the reduced partitioning node LP.
fn node_shaped_lp(mu: usize, tau: usize, seed: u64) -> Problem {
    let mut rng = Rng::new(seed);
    let mut p = Problem::new();
    let vars: Vec<_> = (0..mu * tau)
        .map(|k| p.cont(&format!("a{k}"), 0.0, 1.0))
        .collect();
    let f = p.cont("f", 0.0, f64::INFINITY);
    for j in 0..tau {
        let terms: Vec<_> = (0..mu).map(|i| (vars[i * tau + j], 1.0)).collect();
        p.constrain(terms, Cmp::Eq, 1.0);
    }
    for i in 0..mu {
        let mut terms: Vec<_> = (0..tau)
            .map(|j| (vars[i * tau + j], rng.range_f64(1.0, 100.0)))
            .collect();
        terms.push((f, -1.0));
        p.constrain(terms, Cmp::Le, 0.0);
    }
    p.minimize(vec![(f, 1.0)]);
    p
}

fn main() {
    println!("== perf: simplex ==");
    for (mu, tau) in [(4, 16), (8, 64), (16, 128)] {
        let lp = node_shaped_lp(mu, tau, 7);
        common::measure(&format!("simplex {mu}x{tau} node LP"), 5, || {
            let sol = simplex::solve(&lp);
            assert_eq!(sol.status, cloudshapes::milp::LpStatus::Optimal);
        });
    }

    println!("\n== perf: partitioners at paper scale (16x128) ==");
    let models = paper_models();
    common::measure("heuristic partition (budgeted sweep)", 5, || {
        let h = HeuristicPartitioner::default();
        h.partition(&models, Some(8.0)).unwrap();
    });
    for nodes in [1usize, 50, 200] {
        let cfg = MilpConfig { max_nodes: nodes, time_limit_secs: 120.0, ..Default::default() };
        let p = MilpPartitioner::new(cfg);
        let mut makespan = 0.0;
        let med = common::measure(&format!("milp solve ({nodes} nodes budget)"), 3, || {
            let out = p.solve(&models, Some(8.0)).unwrap();
            makespan = out.makespan;
        });
        println!("        -> makespan {makespan:.0}s at {med:.2}s solve time");
    }
    println!("perf_solver bench OK");
}
