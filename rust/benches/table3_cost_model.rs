//! Regenerates **Table III**: the Uptime-Institute-style TCO model applied
//! to hypothetical FPGA/GPU/CPU IaaS offerings — calculated device rates vs
//! the observed April-2015 market rates.

mod common;

use cloudshapes::models::tco::{self, DatacentreModel};
use cloudshapes::report;

fn main() {
    let (table, _) = common::timed("table3", report::table3);
    let rendered = table.render();
    println!("\n{rendered}");
    common::save("table3.txt", &rendered);
    common::save("table3.csv", &table.to_csv());

    // The paper's calculated rates, to the cent.
    let dc = DatacentreModel::default();
    let checks = [
        ("FPGA", tco::table3::FPGA.device_base_rate(&dc), tco::table3::CALCULATED_FPGA),
        ("GPU", tco::table3::GPU.device_base_rate(&dc), tco::table3::CALCULATED_GPU),
        ("CPU", tco::table3::CPU.device_base_rate(&dc), tco::table3::CALCULATED_CPU),
    ];
    println!("{:>6} {:>12} {:>10}", "device", "calculated", "paper");
    for (name, got, want) in checks {
        println!("{name:>6} {got:>12.4} {want:>10.2}");
        assert!((got - want).abs() < 0.005, "{name}: {got} vs paper {want}");
    }
    // Calculated < observed by a few percent (§IV.C.1).
    assert!(tco::table3::GPU.device_base_rate(&dc) < tco::table3::OBSERVED_GPU);
    assert!(tco::table3::CPU.device_base_rate(&dc) < tco::table3::OBSERVED_CPU);
    println!("table3 bench OK");
}
