//! Regenerates **Figure 3**: partitioner model predictions vs measured
//! execution — every Pareto point of both partitioners is actually run on
//! the (simulated) cluster and compared with its model prediction. Paper:
//! curves close enough to plan with; worst outlier ~12% fast / 7% cheap.

mod common;

use cloudshapes::config::ExperimentConfig;
use cloudshapes::report::{self, Experiment};
use cloudshapes::util::stats::percentile;

fn main() {
    let mut cfg = ExperimentConfig::default();
    cfg.sweep.levels = 7;
    let (e, _) = common::timed("build paper experiment", || {
        Experiment::build(cfg).expect("experiment")
    });
    let ((plot, points), _) = common::timed("fig3 (sweep both + execute every point)", || {
        report::fig3(&e).expect("fig3")
    });
    let rendered = plot.render();
    println!("\n{rendered}");
    common::save("fig3.txt", &rendered);
    common::save("fig3.csv", &report::fig3_csv(&points));

    println!(
        "{:>10} {:>12} {:>14} {:>14} {:>9}",
        "partnr", "budget", "model (s/$)", "measured (s/$)", "lat err"
    );
    let mut errs = Vec::new();
    for p in &points {
        let err = (p.measured_latency - p.model_latency) / p.model_latency;
        errs.push(err.abs());
        println!(
            "{:>10} {:>12} {:>7.0}/{:<6.2} {:>7.0}/{:<6.2} {:>8.1}%",
            p.partitioner,
            p.budget.map(|b| format!("{b:.2}")).unwrap_or_else(|| "uncon".into()),
            p.model_latency,
            p.model_cost,
            p.measured_latency,
            p.measured_cost,
            err * 100.0
        );
    }
    let median = percentile(&errs, 50.0);
    let worst = percentile(&errs, 100.0);
    println!("latency prediction error: median {:.1}%, worst {:.1}%", median * 100.0, worst * 100.0);
    assert!(median < 0.10, "median model-vs-measured error {median}");
    assert!(worst < 0.30, "worst model-vs-measured error {worst}");
    println!("fig3 bench OK");
}
