//! Perf: the cluster-shape optimiser — fixed Table II testbed vs an
//! optimised composition at the SAME deadline (billed-cost comparison plus
//! wall-clock of the outer search). Emits `results/BENCH_shape.json` so the
//! perf trajectory accumulates data across PRs.
//!
//! Pass `--smoke` (the CI mode) to shrink the catalogue/workload so the
//! bench acts as a fast regression gate: the optimised shape must never
//! bill more than the fixed testbed at an equal deadline.

mod common;

use cloudshapes::coordinator::{
    sweep, HeuristicPartitioner, ModelSet, ShapeObjective, ShapeSearch, SweepConfig,
};
use cloudshapes::milp::BnbLimits;
use cloudshapes::platforms::catalogue::Catalogue;
use cloudshapes::util::json::{obj, Json};
use cloudshapes::workload::{generate, GeneratorConfig};

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let catalogue = if smoke { Catalogue::small() } else { Catalogue::paper() };
    let workload = if smoke {
        generate(&GeneratorConfig::small(8, 0.02, 7))
    } else {
        generate(&GeneratorConfig { n_tasks: 64, ..GeneratorConfig::default() })
    };
    // Per-type nominal models: one row-set per catalogue offer.
    let type_specs: Vec<_> = catalogue.offers().iter().map(|o| o.spec.clone()).collect();
    let types = ModelSet::from_specs(&type_specs, &workload);
    let avail = catalogue.availability();
    let testbed_counts = catalogue.testbed_counts();

    println!(
        "== perf: shape search ({} offers, {} tasks, testbed {:?}) ==",
        catalogue.len(),
        workload.len(),
        testbed_counts
    );

    // Fixed testbed: the paper heuristic's sweep over the pinned counts.
    let heuristic = HeuristicPartitioner::default();
    let testbed = types.replicate(&testbed_counts).expect("testbed instantiates");
    let curve = sweep(&heuristic, &testbed, &SweepConfig { levels: 9 }).unwrap();
    // Deadline: midway between the testbed's fastest point and 2x it —
    // binding enough that compositions matter, loose enough to be feasible.
    let fastest = curve
        .points
        .iter()
        .map(|p| p.latency)
        .fold(f64::INFINITY, f64::min);
    let deadline = fastest * 1.5;
    let fixed_cost = curve
        .points
        .iter()
        .filter(|p| p.latency <= deadline + 1e-9)
        .map(|p| p.cost)
        .fold(f64::INFINITY, f64::min);
    println!(
        "[perf] fixed testbed: fastest {fastest:.1}s, best cost within {deadline:.1}s \
         deadline ${fixed_cost:.3}"
    );

    let limits = BnbLimits { time_limit_secs: 30.0, ..BnbLimits::default() };
    let search = ShapeSearch::new(&types, &avail, &heuristic, limits)
        .expect("valid catalogue")
        .with_baseline(testbed_counts.clone())
        .expect("testbed fits availability");
    let runs = if smoke { 1 } else { 3 };
    let mut out = None;
    let wall = common::measure("optimize_shape(deadline)", runs, || {
        out = Some(search.optimize(ShapeObjective::Deadline(deadline)).unwrap());
    });
    let out = out.unwrap();
    println!(
        "[perf] optimised shape {:?}: {:.1}s, ${:.3} (bound ${:.3}, {} outer nodes, \
         {:.0}% of fixed cost)",
        out.point.counts,
        out.point.latency,
        out.point.cost,
        out.outer_bound,
        out.nodes,
        100.0 * out.point.cost / fixed_cost
    );

    // Regression gate: at an equal deadline the optimised composition must
    // not bill materially more than the fixed testbed's best heuristic
    // allocation (the testbed rides along as the search baseline; the small
    // slack absorbs budget-grid differences between the two sweeps).
    assert!(out.point.latency <= deadline + 1e-9, "shape missed the deadline");
    assert!(
        out.point.cost <= fixed_cost * 1.05 + 1e-9,
        "optimised shape (${}) billed more than the fixed testbed (${fixed_cost})",
        out.point.cost
    );

    let json = obj(vec![
        ("smoke", Json::Bool(smoke)),
        ("offers", catalogue.len().into()),
        ("tasks", workload.len().into()),
        ("deadline_s", deadline.into()),
        ("fixed_testbed_cost", fixed_cost.into()),
        ("shape_cost", out.point.cost.into()),
        ("shape_latency_s", out.point.latency.into()),
        (
            "shape_counts",
            Json::Arr(out.point.counts.iter().map(|&c| c.into()).collect()),
        ),
        ("outer_bound", out.outer_bound.into()),
        ("outer_nodes", out.nodes.into()),
        ("search_wall_s", wall.into()),
    ]);
    common::save("BENCH_shape.json", &json.to_string_pretty());
    println!("perf_shape bench OK");
}
