"""AOT pipeline tests: lowering, manifest schema, HLO text sanity."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out), variants=[("european", 4096, 1), ("asian", 4096, 8)], quiet=True)
    return str(out), manifest


def test_manifest_written(built):
    out, manifest = built
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert on_disk["schema"] == 1
    assert len(on_disk["variants"]) == 2


def test_hlo_text_is_parseable_hlo(built):
    out, manifest = built
    for v in manifest["variants"]:
        text = open(os.path.join(out, v["file"])).read()
        assert text.startswith("HloModule")
        assert "ENTRY" in text
        # The signature the rust loader marshals against.
        assert "f32[8]" in text and "u32[2]" in text and "u32[1]" in text
        assert "(f32[], f32[])" in text


def test_manifest_signature_schema(built):
    _, manifest = built
    for v in manifest["variants"]:
        assert [i["dtype"] for i in v["inputs"]] == ["f32", "u32", "u32"]
        assert [i["shape"] for i in v["inputs"]] == [[8], [2], [1]]
        assert [o["shape"] for o in v["outputs"]] == [[], []]
        assert v["n"] % v["block"] == 0


def test_sha256_matches_file(built):
    import hashlib

    out, manifest = built
    for v in manifest["variants"]:
        text = open(os.path.join(out, v["file"])).read()
        assert hashlib.sha256(text.encode()).hexdigest() == v["sha256"]


def test_variant_names_unique():
    names = [aot.variant_name(*v) for v in aot.DEFAULT_VARIANTS]
    assert len(names) == len(set(names))


def test_lowered_hlo_has_no_custom_calls(built):
    """interpret=True must fully inline the kernel: a Mosaic custom-call here
    would make the artifact unloadable on the CPU PJRT client."""
    out, manifest = built
    for v in manifest["variants"]:
        text = open(os.path.join(out, v["file"])).read()
        assert "custom-call" not in text, f"{v['name']} contains a custom-call"
