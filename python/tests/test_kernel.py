"""Pallas kernel vs pure-jnp oracle: the CORE L1 correctness signal.

Hypothesis sweeps shapes (n, block), payoff families and market parameters;
every case asserts ``assert_allclose`` against ``ref.simulate_chunk_ref``.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mc, ref


def make_params(s0, k, r, sigma, t, barrier=150.0):
    return jnp.array([s0, k, r, sigma, t, barrier, 0.0, 0.0], jnp.float32)


def make_key(a=7, b=42):
    return jnp.array([a, b], jnp.uint32)


def make_offset(o=0):
    return jnp.array([o], jnp.uint32)


DEFAULT = dict(params=make_params(100.0, 105.0, 0.05, 0.2, 1.0), key=make_key(), offset=make_offset())


def run_both(payoff, n, steps=8, block=256, **kw):
    a = dict(DEFAULT)
    a.update(kw)
    out_k = mc.simulate_chunk(a["params"], a["key"], a["offset"], payoff=payoff, n=n, steps=steps, block=block)
    out_r = ref.simulate_chunk_ref(a["params"], a["key"], a["offset"], payoff=payoff, n=n, steps=steps, block=block)
    return np.asarray(out_k), np.asarray(out_r)


@pytest.mark.parametrize("payoff", mc.PAYOFFS)
def test_kernel_matches_ref_basic(payoff):
    out_k, out_r = run_both(payoff, n=1024, steps=8, block=256)
    assert out_k.shape == (4, 2)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5)


@pytest.mark.parametrize("payoff", mc.PAYOFFS)
@pytest.mark.parametrize("n,block", [(256, 256), (512, 128), (2048, 512), (4096, 4096)])
def test_kernel_shapes(payoff, n, block):
    out_k, out_r = run_both(payoff, n=n, steps=4, block=block)
    assert out_k.shape == (n // block, 2)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5)


@settings(max_examples=15, deadline=None)
@given(
    payoff=st.sampled_from(mc.PAYOFFS),
    s0=st.floats(50.0, 200.0),
    k=st.floats(50.0, 200.0),
    r=st.floats(0.0, 0.1),
    sigma=st.floats(0.05, 0.6),
    t=st.floats(0.1, 3.0),
    barrier_mult=st.floats(1.1, 2.5),
    key0=st.integers(0, 2**32 - 1),
    offset=st.integers(0, 2**24),
)
def test_kernel_matches_ref_param_sweep(payoff, s0, k, r, sigma, t, barrier_mult, key0, offset):
    params = make_params(s0, k, r, sigma, t, barrier=s0 * barrier_mult)
    out_k, out_r = run_both(
        payoff, n=512, steps=6, block=128,
        params=params, key=make_key(key0, 1), offset=make_offset(offset),
    )
    np.testing.assert_allclose(out_k, out_r, rtol=5e-5, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(
    log2_block=st.integers(5, 10),
    grid=st.integers(1, 6),
    payoff=st.sampled_from(mc.PAYOFFS),
)
def test_kernel_block_shape_sweep(log2_block, grid, payoff):
    """Blocking is purely an execution schedule: results identical across it."""
    block = 1 << log2_block
    n = block * grid
    out_k, out_r = run_both(payoff, n=n, steps=4, block=block)
    np.testing.assert_allclose(out_k, out_r, rtol=2e-5)


def test_block_partition_invariance():
    """Total (sum, sum_sq) must not depend on the block size at all."""
    totals = []
    for block in (128, 256, 1024):
        out_k, _ = run_both("european", n=2048, block=block)
        totals.append(out_k.sum(axis=0))
    np.testing.assert_allclose(totals[0], totals[1], rtol=1e-5)
    np.testing.assert_allclose(totals[0], totals[2], rtol=1e-5)


def test_chunk_offset_composition():
    """Two n/2 chunks with advanced offset == one n chunk (path-space split)."""
    a = dict(DEFAULT)
    whole, _ = run_both("european", n=2048, block=256)
    lo = mc.simulate_chunk(a["params"], a["key"], make_offset(0), payoff="european", n=1024, block=256)
    hi = mc.simulate_chunk(a["params"], a["key"], make_offset(1024), payoff="european", n=1024, block=256)
    np.testing.assert_allclose(
        whole.sum(axis=0),
        np.asarray(lo).sum(axis=0) + np.asarray(hi).sum(axis=0),
        rtol=1e-5,
    )


def test_kernel_rejects_bad_n():
    a = DEFAULT
    with pytest.raises(ValueError, match="multiple of block"):
        mc.simulate_chunk(a["params"], a["key"], a["offset"], payoff="european", n=1000, block=256)


def test_kernel_rejects_bad_payoff():
    a = DEFAULT
    with pytest.raises(ValueError, match="unknown payoff"):
        mc.simulate_chunk(a["params"], a["key"], a["offset"], payoff="digital", n=256, block=256)


def test_output_dtype_is_f32():
    out_k, _ = run_both("european", n=256, block=256)
    assert out_k.dtype == np.float32


def test_barrier_knockout_monotone_in_barrier():
    """Higher barrier => fewer knock-outs => payoff sum cannot decrease."""
    sums = []
    for b in (110.0, 130.0, 1e6):
        params = make_params(100.0, 105.0, 0.05, 0.2, 1.0, barrier=b)
        out_k, _ = run_both("barrier", n=4096, steps=8, block=512, params=params)
        sums.append(out_k[:, 0].sum())
    assert sums[0] <= sums[1] <= sums[2]


def test_barrier_at_infinity_equals_terminal_path():
    """With an unreachable barrier, the payoff reduces to a European call on
    the *path-discretised* terminal spot (same steps/counters)."""
    params = make_params(100.0, 105.0, 0.05, 0.2, 1.0, barrier=1e7)
    out_b, _ = run_both("barrier", n=2048, steps=8, block=256, params=params)
    p = ref.barrier_paths(params, make_key(), make_offset(), 2048, 8)
    expected = np.asarray(p).reshape(8, 256).sum(axis=1)
    np.testing.assert_allclose(out_b[:, 0], expected, rtol=2e-5)


def test_asian_payoff_below_european_for_same_strike():
    """Averaging reduces volatility: Asian call <= European call (in sum)."""
    out_a, _ = run_both("asian", n=8192, steps=16, block=1024)
    out_e, _ = run_both("european", n=8192, block=1024)
    assert out_a[:, 0].sum() < out_e[:, 0].sum()
