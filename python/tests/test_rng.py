"""RNG correctness: Threefry-2x32 vs jax's own, plus distribution checks."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import rng

uint32s = st.integers(min_value=0, max_value=2**32 - 1)


@settings(max_examples=50, deadline=None)
@given(k0=uint32s, k1=uint32s, base=uint32s)
def test_threefry_matches_jax(k0, k1, base):
    """Our inlined Threefry-2x32 is bit-compatible with jax._src.prng."""
    from jax._src import prng as jprng

    c = (jnp.uint32(base) + jnp.arange(16, dtype=jnp.uint32)).astype(jnp.uint32)
    mine0, mine1 = rng.threefry2x32(jnp.uint32(k0), jnp.uint32(k1), c, c + jnp.uint32(1))
    theirs = jprng.threefry_2x32(
        jnp.array([k0, k1], jnp.uint32), jnp.concatenate([c, c + jnp.uint32(1)])
    )
    np.testing.assert_array_equal(np.asarray(mine0), np.asarray(theirs[:16]))
    np.testing.assert_array_equal(np.asarray(mine1), np.asarray(theirs[16:]))


def test_threefry_deterministic():
    c = jnp.arange(8, dtype=jnp.uint32)
    a = rng.threefry2x32(jnp.uint32(1), jnp.uint32(2), c, c)
    b = rng.threefry2x32(jnp.uint32(1), jnp.uint32(2), c, c)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_threefry_key_sensitivity():
    """Changing one key bit decorrelates the whole stream."""
    c = jnp.arange(1024, dtype=jnp.uint32)
    a, _ = rng.threefry2x32(jnp.uint32(0), jnp.uint32(0), c, c)
    b, _ = rng.threefry2x32(jnp.uint32(1), jnp.uint32(0), c, c)
    assert int(jnp.sum(a == b)) <= 2  # collisions are ~2^-32 each


@settings(max_examples=20, deadline=None)
@given(k0=uint32s, k1=uint32s)
def test_uniforms_in_open_unit_interval(k0, k1):
    c = jnp.arange(4096, dtype=jnp.uint32)
    u0, u1 = rng.uniforms(jnp.uint32(k0), jnp.uint32(k1), c, c + jnp.uint32(9))
    for u in (u0, u1):
        arr = np.asarray(u)
        assert arr.dtype == np.float32
        assert (arr > 0.0).all() and (arr <= 1.0).all()


def test_uniform_moments():
    c = jnp.arange(1 << 16, dtype=jnp.uint32)
    u0, u1 = rng.uniforms(jnp.uint32(3), jnp.uint32(5), c, jnp.zeros_like(c))
    for u in (u0, u1):
        arr = np.asarray(u, np.float64)
        assert abs(arr.mean() - 0.5) < 0.005
        assert abs(arr.var() - 1.0 / 12.0) < 0.005


def test_normal_moments():
    c = jnp.arange(1 << 16, dtype=jnp.uint32)
    z = np.asarray(rng.normal(jnp.uint32(11), jnp.uint32(13), c, jnp.zeros_like(c)), np.float64)
    assert abs(z.mean()) < 0.02
    assert abs(z.std() - 1.0) < 0.02
    # Fourth moment of N(0,1) is 3 — catches broken Box-Muller tails.
    assert abs((z**4).mean() - 3.0) < 0.2


def test_normal_streams_independent_across_steps():
    c = jnp.arange(1 << 14, dtype=jnp.uint32)
    z0 = np.asarray(rng.normal(jnp.uint32(1), jnp.uint32(1), c, jnp.zeros_like(c)), np.float64)
    z1 = np.asarray(rng.normal(jnp.uint32(1), jnp.uint32(1), c, jnp.ones_like(c)), np.float64)
    corr = np.corrcoef(z0, z1)[0, 1]
    assert abs(corr) < 0.03


def test_counter_bijectivity_under_offset():
    """Chunked execution invariant: offset+i must equal a shifted stream."""
    c = jnp.arange(128, dtype=jnp.uint32)
    whole = rng.normal(jnp.uint32(2), jnp.uint32(4), c, jnp.zeros_like(c))
    lo = rng.normal(jnp.uint32(2), jnp.uint32(4), c[:64], jnp.zeros((64,), jnp.uint32))
    hi = rng.normal(
        jnp.uint32(2), jnp.uint32(4), jnp.uint32(64) + c[:64], jnp.zeros((64,), jnp.uint32)
    )
    np.testing.assert_array_equal(np.asarray(whole), np.concatenate([lo, hi]))
