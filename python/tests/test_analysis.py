"""Structural-analysis invariants: the kernel design goals of DESIGN.md
§Hardware-Adaptation, checked as numbers rather than prose."""

import pytest
from hypothesis import given, settings, strategies as st

from compile import analysis


@pytest.mark.parametrize("payoff", ["european", "asian", "barrier"])
def test_vmem_working_set_is_small(payoff):
    p = analysis.profile(payoff, block=4096, steps=512)
    # Design goal: block working set well under 10% of VMEM so double
    # buffering and multiple concurrent blocks are trivially possible.
    assert p.vmem_utilisation < 0.10, p.vmem_bytes


@pytest.mark.parametrize("payoff", ["european", "asian", "barrier"])
def test_kernels_are_compute_bound(payoff):
    p = analysis.profile(payoff)
    assert p.compute_bound
    # O(1) HBM traffic per block => enormous arithmetic intensity.
    assert p.arithmetic_intensity > 1e4


@settings(max_examples=30, deadline=None)
@given(
    log2_block=st.integers(7, 14),
    steps=st.integers(1, 1024),
    payoff=st.sampled_from(["european", "asian", "barrier"]),
)
def test_block_scaling_invariants(log2_block, steps, payoff):
    block = 1 << log2_block
    p = analysis.profile(payoff, block=block, steps=steps)
    # VMEM grows linearly with block; stays within budget up to 16k paths.
    assert p.vmem_bytes < analysis.VMEM_BYTES
    # HBM per path shrinks with block (better amortisation).
    bigger = analysis.profile(payoff, block=block * 2, steps=steps)
    assert bigger.hbm_bytes_per_path < p.hbm_bytes_per_path


def test_european_is_single_step():
    p = analysis.profile("european", steps=512)
    assert p.steps == 1  # terminal-value simulation ignores the steps knob


def test_ops_match_rust_flops_model():
    """The rust coordinator's flops_per_path (workload/option.rs) and this
    analysis must agree on the step cost, or the simulated platform
    throughputs drift away from the kernel the native platform runs."""
    p = analysis.profile("asian", steps=64)
    # rust: steps * (130 + 12) + 25
    rust_flops = 64 * (130 + 12) + 25
    assert abs(p.alu_ops_per_path - rust_flops) / rust_flops < 0.10


def test_report_renders():
    out = analysis.report(4096, 64)
    assert "european" in out and "barrier" in out
    assert "compute" in out
