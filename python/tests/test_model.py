"""L2 model tests: chunk pricing statistics vs closed forms."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def price(payoff, params_list, n=1 << 16, steps=32, key=(7, 42)):
    params = jnp.array(list(params_list) + [0.0] * (8 - len(params_list)), jnp.float32)
    key = jnp.array(key, jnp.uint32)
    off = jnp.array([0], jnp.uint32)
    s, s2 = model.price_chunk(params, key, off, payoff=payoff, n=n, steps=steps)
    r, t = float(params[2]), float(params[4])
    return model.mc_estimate(float(s), float(s2), n, r, t)


def test_european_matches_black_scholes():
    p, se = price("european", [100.0, 105.0, 0.05, 0.2, 1.0])
    bs = float(ref.black_scholes_call(100.0, 105.0, 0.05, 0.2, 1.0))
    assert abs(p - bs) < 4 * se + 0.03, (p, se, bs)


@settings(max_examples=8, deadline=None)
@given(
    s0=st.floats(80.0, 120.0),
    k_rel=st.floats(0.8, 1.2),
    sigma=st.floats(0.1, 0.4),
    t=st.floats(0.25, 2.0),
)
def test_european_matches_black_scholes_sweep(s0, k_rel, sigma, t):
    k = s0 * k_rel
    p, se = price("european", [s0, k, 0.03, sigma, t], n=1 << 15)
    bs = float(ref.black_scholes_call(s0, k, 0.03, sigma, t))
    assert abs(p - bs) < 5 * se + 0.05, (p, se, bs)


def test_asian_bracketed_by_geometric_and_european():
    args = [100.0, 100.0, 0.05, 0.25, 1.0]
    p, se = price("asian", args, steps=32)
    geo = float(ref.geometric_asian_call(*args, steps=32))
    bs = float(ref.black_scholes_call(*args))
    assert geo - 4 * se - 0.05 < p < bs + 4 * se, (geo, p, bs)


def test_barrier_below_european():
    p_b, se = price("barrier", [100.0, 105.0, 0.05, 0.25, 1.0, 130.0], steps=32)
    bs = float(ref.black_scholes_call(100.0, 105.0, 0.05, 0.25, 1.0))
    assert p_b < bs
    assert p_b >= 0.0


def test_stderr_shrinks_with_n():
    _, se_small = price("european", [100.0, 105.0, 0.05, 0.2, 1.0], n=1 << 13)
    _, se_big = price("european", [100.0, 105.0, 0.05, 0.2, 1.0], n=1 << 17)
    # sqrt(16) = 4x reduction expected; allow slack for sampling noise.
    assert se_big < se_small / 2.5


def test_mc_estimate_agrees_with_numpy():
    rng = np.random.default_rng(0)
    x = rng.exponential(2.0, size=10_000).astype(np.float32)
    p, se = model.mc_estimate(float(x.sum()), float((x * x).sum()), x.size, 0.0, 1.0)
    assert abs(p - x.mean()) < 1e-4
    assert abs(se - x.std() / np.sqrt(x.size)) < 1e-4


def test_seed_changes_estimate_but_not_beyond_stderr():
    p1, se1 = price("european", [100.0, 105.0, 0.05, 0.2, 1.0], key=(7, 1))
    p2, se2 = price("european", [100.0, 105.0, 0.05, 0.2, 1.0], key=(7, 2))
    assert p1 != p2  # different seeds genuinely resample
    assert abs(p1 - p2) < 6 * (se1 + se2)
