"""Pure-jnp oracle for the Pallas kernels, plus closed-form Black-Scholes.

``simulate_chunk_ref`` mirrors the counter layout of ``mc.simulate_chunk``
exactly (path ``p`` uses counters ``(offset + p, step)``), so the Pallas
kernels must match it bit-for-bit up to float-associativity in the block
reductions. pytest enforces ``assert_allclose`` with tight tolerances.
"""

import jax
import jax.numpy as jnp

from . import rng


def _normals(key, offset, n, step):
    ctr0 = jnp.asarray(offset[0], jnp.uint32) + jax.lax.iota(jnp.uint32, n)
    ctr1 = jnp.full((n,), jnp.uint32(step))
    return rng.normal(key[0], key[1], ctr0, ctr1)


def european_paths(params, key, offset, n):
    """Terminal spot payoffs for the European call. Returns f32[n]."""
    s0, k, r, sigma, t = (params[i] for i in range(5))
    z = _normals(key, offset, n, 0)
    drift = (r - jnp.float32(0.5) * sigma * sigma) * t
    st = s0 * jnp.exp(drift + sigma * jnp.sqrt(t) * z)
    return jnp.maximum(st - k, jnp.float32(0.0))


def asian_paths(params, key, offset, n, steps):
    """Arithmetic-average Asian call payoffs. Returns f32[n]."""
    s0, k, r, sigma, t = (params[i] for i in range(5))
    dt = t / jnp.float32(steps)
    drift = (r - jnp.float32(0.5) * sigma * sigma) * dt
    vol = sigma * jnp.sqrt(dt)
    log_s = jnp.log(s0) * jnp.ones((n,), jnp.float32)
    acc = jnp.zeros((n,), jnp.float32)
    for step in range(steps):
        z = _normals(key, offset, n, step)
        log_s = log_s + drift + vol * z
        acc = acc + jnp.exp(log_s)
    avg = acc / jnp.float32(steps)
    return jnp.maximum(avg - k, jnp.float32(0.0))


def barrier_paths(params, key, offset, n, steps):
    """Up-and-out barrier call payoffs. Returns f32[n]."""
    s0, k, r, sigma, t, barrier = (params[i] for i in range(6))
    dt = t / jnp.float32(steps)
    drift = (r - jnp.float32(0.5) * sigma * sigma) * dt
    vol = sigma * jnp.sqrt(dt)
    log_s = jnp.log(s0) * jnp.ones((n,), jnp.float32)
    alive = jnp.ones((n,), jnp.bool_) & (s0 < barrier)
    for step in range(steps):
        z = _normals(key, offset, n, step)
        log_s = log_s + drift + vol * z
        alive = alive & (jnp.exp(log_s) < barrier)
    st = jnp.exp(log_s)
    return jnp.where(alive, jnp.maximum(st - k, jnp.float32(0.0)), jnp.float32(0.0))


def simulate_chunk_ref(params, key, offset, *, payoff, n, steps=64, block=4096):
    """Reference implementation of ``mc.simulate_chunk``: f32[n//block, 2]."""
    if payoff == "european":
        p = european_paths(params, key, offset, n)
    elif payoff == "asian":
        p = asian_paths(params, key, offset, n, steps)
    elif payoff == "barrier":
        p = barrier_paths(params, key, offset, n, steps)
    else:
        raise ValueError(f"unknown payoff {payoff!r}")
    p = p.reshape(n // block, block)
    return jnp.stack([jnp.sum(p, axis=1), jnp.sum(p * p, axis=1)], axis=1)


# --- Closed forms -----------------------------------------------------------

def _norm_cdf(x):
    return jnp.float32(0.5) * (jnp.float32(1.0) + jax.lax.erf(x / jnp.sqrt(jnp.float32(2.0))))


def black_scholes_call(s0, k, r, sigma, t):
    """Closed-form Black-Scholes European call price (discounted)."""
    s0, k, r, sigma, t = map(jnp.float32, (s0, k, r, sigma, t))
    d1 = (jnp.log(s0 / k) + (r + 0.5 * sigma * sigma) * t) / (sigma * jnp.sqrt(t))
    d2 = d1 - sigma * jnp.sqrt(t)
    return s0 * _norm_cdf(d1) - k * jnp.exp(-r * t) * _norm_cdf(d2)


def geometric_asian_call(s0, k, r, sigma, t, steps):
    """Closed-form geometric-average Asian call (Kemna-Vorst, discrete fixings).

    A sanity *lower bound* for the arithmetic Asian MC price (arithmetic
    mean >= geometric mean => arithmetic Asian call >= geometric one).
    """
    s0, k, r, sigma, t = map(jnp.float32, (s0, k, r, sigma, t))
    m = steps
    dt = t / m
    mu = (r - 0.5 * sigma * sigma) * dt * (m + 1) / 2.0
    var = sigma * sigma * dt * (m + 1) * (2 * m + 1) / (6.0 * m)
    sig_g = jnp.sqrt(var)
    d1 = (jnp.log(s0 / k) + mu + var) / sig_g
    d2 = d1 - sig_g
    fwd = s0 * jnp.exp(mu + 0.5 * var)
    return jnp.exp(-r * t) * (fwd * _norm_cdf(d1) - k * _norm_cdf(d2))
