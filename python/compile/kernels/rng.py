"""Counter-based RNG primitives shared by the Pallas kernels and the ref oracle.

The paper's Monte Carlo hot loop is dominated by random-number generation
(§IV.A.1: "random generation accounting for the bulk of the computations").
The FPGA designs it benchmarks pipeline Tausworthe/Mersenne generators; the
TPU-shaped equivalent (DESIGN.md §Hardware-Adaptation) is a *counter-based*
generator: Threefry-2x32, which is pure ALU work, needs no carried state, and
vectorises across lanes.

Everything here is plain ``jnp`` so the same code runs inside a Pallas kernel
(interpret mode), in the pure-jnp reference oracle, and under jit.
"""

import jax.numpy as jnp
import numpy as np

# Threefry-2x32 rotation schedule (Salmon et al., SC'11), 20 rounds.
_ROTATIONS = (13, 15, 26, 6, 17, 29, 16, 24)
# SKEIN key-schedule parity constant for the 32-bit variant. A *numpy* scalar
# on purpose: a jax array created at import time would be closure-captured by
# the Pallas kernels and rejected ("captures constants").
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, d):
    """Rotate the uint32 lanes of ``x`` left by the static amount ``d``."""
    x = x.astype(jnp.uint32)
    return (x << d) | (x >> (32 - d))


def threefry2x32(k0, k1, x0, x1):
    """Threefry-2x32, 20 rounds. All args are uint32 arrays (broadcastable).

    Returns a pair of uint32 arrays. Bit-compatible with
    ``jax._src.prng.threefry_2x32`` (tested in ``python/tests/test_rng.py``).
    """
    k0 = jnp.asarray(k0, jnp.uint32)
    k1 = jnp.asarray(k1, jnp.uint32)
    x0 = jnp.asarray(x0, jnp.uint32)
    x1 = jnp.asarray(x1, jnp.uint32)
    ks = (k0, k1, k0 ^ k1 ^ _PARITY)

    x0 = x0 + ks[0]
    x1 = x1 + ks[1]
    for block in range(5):
        for r in range(4):
            x0 = x0 + x1
            x1 = _rotl(x1, _ROTATIONS[(4 * block + r) % 8])
            x1 = x1 ^ x0
        # Key injection after every 4 rounds, with the round-block counter
        # folded into the second word (Skein/Threefry schedule).
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + jnp.uint32(block + 1)
    return x0, x1


def uniforms(k0, k1, ctr0, ctr1):
    """Two independent U(0,1] streams from one Threefry call.

    Uses the top 24 bits of each output word so the result is exactly
    representable in float32 and never 0 (offset by half an ulp).
    """
    r0, r1 = threefry2x32(k0, k1, ctr0, ctr1)
    scale = jnp.float32(1.0 / (1 << 24))
    u0 = (r0 >> 8).astype(jnp.float32) * scale + jnp.float32(0.5 / (1 << 24))
    u1 = (r1 >> 8).astype(jnp.float32) * scale + jnp.float32(0.5 / (1 << 24))
    return u0, u1


def box_muller(u0, u1):
    """Box-Muller transform: two U(0,1] streams -> two N(0,1) streams."""
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(u0))
    theta = jnp.float32(2.0 * jnp.pi) * u1
    return r * jnp.cos(theta), r * jnp.sin(theta)


def normal(k0, k1, ctr0, ctr1):
    """One N(0,1) stream per (ctr0, ctr1) counter pair.

    The second Box-Muller output is deliberately discarded: it keeps the
    counter -> sample map bijective, which is what makes chunked execution
    on the rust side order-independent.
    """
    u0, u1 = uniforms(k0, k1, ctr0, ctr1)
    z0, _ = box_muller(u0, u1)
    return z0
