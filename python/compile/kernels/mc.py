"""L1 Pallas kernels: Monte Carlo option-payoff simulation.

One kernel per payoff family the Kaiserslautern benchmark covers:

* ``european`` — terminal-value GBM, one normal per path;
* ``asian``    — arithmetic-average path (fixing dates = ``steps``);
* ``barrier``  — up-and-out call, knock-out monitored at each step.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the ``n``-path axis is
tiled into ``block`` sized chunks via the Pallas grid + BlockSpec, so each
block's working set (a handful of f32[block] vectors) sits comfortably in
VMEM; randomness is generated in-lane with Threefry-2x32 (no memory traffic);
each block reduces its payoffs to a single ``(sum, sum_sq)`` pair so HBM
writeback is O(1) per block. The kernels are VPU-bound — there is no matmul,
so the MXU is idle by construction and the roofline comparison in
EXPERIMENTS.md §Perf is against the vector unit.

``interpret=True`` everywhere: real-TPU lowering emits a Mosaic custom-call
the CPU PJRT plugin cannot execute (see /opt/xla-example/README.md).

Parameter vector layout (f32[8], shared with the rust coordinator —
``rust/src/workload/option.rs`` must agree):

    0: spot S0      1: strike K    2: risk-free r   3: volatility sigma
    4: maturity T   5: barrier B   6: (reserved)    7: (reserved)

Counter layout: path ``p`` of the overall task stream uses counters
``(offset + p, step)`` under key ``(k0, k1)``; chunked execution advances
``offset`` by the chunk size, so any partition of the path space yields the
same multiset of samples.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import rng

# Default number of paths simulated per Pallas block. 4096 f32 lanes x ~8 live
# vectors = 128 KiB of VMEM — far below the ~16 MiB budget; chosen so the
# threefry ALU chain, not memory, is the bottleneck.
DEFAULT_BLOCK = 4096

PAYOFFS = ("european", "asian", "barrier")


def _lane_counters(block):
    """Global path indices for the current block as uint32."""
    base = (pl.program_id(0) * block).astype(jnp.uint32)
    lanes = jax.lax.iota(jnp.uint32, block)
    return base + lanes


def _reduce_out(o_ref, payoff):
    """Write this block's (sum, sum of squares) partial reduction."""
    o_ref[0, 0] = jnp.sum(payoff)
    o_ref[0, 1] = jnp.sum(payoff * payoff)


def european_kernel(params_ref, key_ref, off_ref, o_ref, *, block):
    """Terminal-value GBM European call: one normal per path."""
    s0, k, r, sigma, t = (params_ref[i] for i in range(5))
    k0, k1 = key_ref[0], key_ref[1]
    ctr0 = off_ref[0] + _lane_counters(block)

    z = rng.normal(k0, k1, ctr0, jnp.zeros_like(ctr0))
    drift = (r - jnp.float32(0.5) * sigma * sigma) * t
    st = s0 * jnp.exp(drift + sigma * jnp.sqrt(t) * z)
    payoff = jnp.maximum(st - k, jnp.float32(0.0))
    _reduce_out(o_ref, payoff)


def asian_kernel(params_ref, key_ref, off_ref, o_ref, *, block, steps):
    """Arithmetic-average Asian call over ``steps`` fixing dates."""
    s0, k, r, sigma, t = (params_ref[i] for i in range(5))
    k0, k1 = key_ref[0], key_ref[1]
    ctr0 = off_ref[0] + _lane_counters(block)

    dt = t / jnp.float32(steps)
    drift = (r - jnp.float32(0.5) * sigma * sigma) * dt
    vol = sigma * jnp.sqrt(dt)

    def body(step, carry):
        log_s, acc = carry
        z = rng.normal(k0, k1, ctr0, jnp.full_like(ctr0, step.astype(jnp.uint32)))
        log_s = log_s + drift + vol * z
        return log_s, acc + jnp.exp(log_s)

    log_s0 = jnp.log(s0) * jnp.ones((block,), jnp.float32)
    _, acc = jax.lax.fori_loop(0, steps, body, (log_s0, jnp.zeros((block,), jnp.float32)))
    avg = acc / jnp.float32(steps)
    payoff = jnp.maximum(avg - k, jnp.float32(0.0))
    _reduce_out(o_ref, payoff)


def barrier_kernel(params_ref, key_ref, off_ref, o_ref, *, block, steps):
    """Up-and-out barrier call, knock-out monitored at each of ``steps`` dates."""
    s0, k, r, sigma, t, barrier = (params_ref[i] for i in range(6))
    k0, k1 = key_ref[0], key_ref[1]
    ctr0 = off_ref[0] + _lane_counters(block)

    dt = t / jnp.float32(steps)
    drift = (r - jnp.float32(0.5) * sigma * sigma) * dt
    vol = sigma * jnp.sqrt(dt)

    def body(step, carry):
        log_s, alive = carry
        z = rng.normal(k0, k1, ctr0, jnp.full_like(ctr0, step.astype(jnp.uint32)))
        log_s = log_s + drift + vol * z
        alive = alive & (jnp.exp(log_s) < barrier)
        return log_s, alive

    log_s0 = jnp.log(s0) * jnp.ones((block,), jnp.float32)
    alive0 = jnp.ones((block,), jnp.bool_) & (s0 < barrier)
    log_st, alive = jax.lax.fori_loop(0, steps, body, (log_s0, alive0))
    st = jnp.exp(log_st)
    payoff = jnp.where(alive, jnp.maximum(st - k, jnp.float32(0.0)), jnp.float32(0.0))
    _reduce_out(o_ref, payoff)


@functools.partial(jax.jit, static_argnames=("payoff", "n", "steps", "block"))
def simulate_chunk(params, key, offset, *, payoff, n, steps=64, block=DEFAULT_BLOCK):
    """Simulate ``n`` paths of ``payoff`` and return per-block partial sums.

    Args:
        params: f32[8] parameter vector (layout in the module docstring).
        key:    u32[2] Threefry key (task id, seed).
        offset: u32[1] starting path counter.
        payoff: one of ``PAYOFFS``.
        n:      number of paths; must be a multiple of ``block``.
        steps:  fixing/monitoring dates for path-dependent payoffs.
        block:  Pallas block size along the path axis.

    Returns:
        f32[n // block, 2] — per-block ``(sum, sum_sq)`` payoff reductions.
    """
    if n % block != 0:
        raise ValueError(f"n={n} must be a multiple of block={block}")
    grid = n // block

    if payoff == "european":
        kern = functools.partial(european_kernel, block=block)
    elif payoff == "asian":
        kern = functools.partial(asian_kernel, block=block, steps=steps)
    elif payoff == "barrier":
        kern = functools.partial(barrier_kernel, block=block, steps=steps)
    else:
        raise ValueError(f"unknown payoff {payoff!r}")

    return pl.pallas_call(
        kern,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((8,), lambda i: (0,)),       # params: broadcast
            pl.BlockSpec((2,), lambda i: (0,)),       # key: broadcast
            pl.BlockSpec((1,), lambda i: (0,)),       # offset: broadcast
        ],
        out_specs=pl.BlockSpec((1, 2), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((grid, 2), jnp.float32),
        interpret=True,
    )(params, key, offset)
