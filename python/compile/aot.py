"""AOT-lower every chunk variant to HLO *text* + a manifest for the rust side.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax>=0.5
emits protos with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Run as ``python -m compile.aot --out ../artifacts`` (what ``make artifacts``
does). Python never runs again after this: the rust binary loads
``artifacts/manifest.json`` and the ``*.hlo.txt`` modules it lists.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model
from .kernels import mc

# The variant set the rust runtime expects. Chunk sizes are powers of two so
# platforms can greedily cover any N; steps is fixed per path-dependent
# variant (it is a static loop bound in the kernel).
DEFAULT_VARIANTS = [
    # (payoff, n, steps)
    ("european", 4096, 1),
    ("european", 16384, 1),
    ("european", 65536, 1),
    ("asian", 4096, 64),
    ("asian", 16384, 64),
    ("barrier", 4096, 64),
    ("barrier", 16384, 64),
]


def variant_name(payoff, n, steps):
    return f"mc_{payoff}_n{n}_s{steps}"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(payoff, n, steps, block=mc.DEFAULT_BLOCK):
    fn = model.chunk_fn(payoff, n, steps, block)
    args = model.example_args()
    specs = [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in args]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def build(out_dir, variants=None, block=mc.DEFAULT_BLOCK, quiet=False):
    """Lower all variants into ``out_dir`` and write ``manifest.json``."""
    variants = variants or DEFAULT_VARIANTS
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for payoff, n, steps in variants:
        name = variant_name(payoff, n, steps)
        text = lower_variant(payoff, n, steps, block)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": name,
                "payoff": payoff,
                "n": n,
                "steps": steps,
                "block": block,
                "file": f"{name}.hlo.txt",
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                # Input signature, for the rust side to validate marshalling.
                "inputs": [
                    {"name": "params", "dtype": "f32", "shape": [8]},
                    {"name": "key", "dtype": "u32", "shape": [2]},
                    {"name": "offset", "dtype": "u32", "shape": [1]},
                ],
                "outputs": [
                    {"name": "payoff_sum", "dtype": "f32", "shape": []},
                    {"name": "payoff_sq_sum", "dtype": "f32", "shape": []},
                ],
            }
        )
        if not quiet:
            print(f"  lowered {name}: {len(text)} chars")
    manifest = {
        "schema": 1,
        "jax_version": jax.__version__,
        "param_layout": ["s0", "strike", "rate", "sigma", "maturity", "barrier", "_r6", "_r7"],
        "variants": entries,
    }
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    if not quiet:
        print(f"wrote {mpath} ({len(entries)} variants)")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--quick", action="store_true", help="smallest variant only (CI)")
    args = ap.parse_args()
    variants = [("european", 4096, 1)] if args.quick else None
    build(args.out, variants)


if __name__ == "__main__":
    main()
