"""L2: the JAX compute graph for one Monte Carlo pricing *chunk*.

A chunk is the unit the rust runtime executes: a fixed number of paths ``n``
of one payoff family, reduced to scalar ``(payoff_sum, payoff_sq_sum)``. The
coordinator prices a task of arbitrary ``N`` by looping chunks with an
advancing path-counter ``offset`` (the counter-based RNG makes the result
independent of how the path space is partitioned).

The chunk graph calls the L1 Pallas kernel (``kernels.mc.simulate_chunk``)
and reduces the per-block partials; the whole thing lowers to ONE fused HLO
module per (payoff, n, steps) variant — see ``aot.py``.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import mc


def chunk_fn(payoff, n, steps=64, block=mc.DEFAULT_BLOCK):
    """Build the chunk-pricing function for a variant.

    Returns ``fn(params f32[8], key u32[2], offset u32[1]) ->
    (sum f32[], sum_sq f32[])`` — payoffs are *undiscounted*; the rust
    coordinator applies ``exp(-rT)`` (discounting there keeps the artifact
    payoff-family-generic and matches how the paper's F3 framework treats
    device results as raw statistics).
    """

    def fn(params, key, offset):
        partials = mc.simulate_chunk(
            params, key, offset, payoff=payoff, n=n, steps=steps, block=block
        )
        return jnp.sum(partials[:, 0]), jnp.sum(partials[:, 1])

    return fn


@functools.partial(jax.jit, static_argnames=("payoff", "n", "steps", "block"))
def price_chunk(params, key, offset, *, payoff, n, steps=64, block=mc.DEFAULT_BLOCK):
    """Convenience jitted entry point used by the python tests."""
    return chunk_fn(payoff, n, steps, block)(params, key, offset)


def mc_estimate(total, total_sq, n, r, t):
    """Combine chunk statistics into a discounted price and std error.

    Mirrors ``rust/src/pricing/mc.rs::combine`` — tested for agreement.
    """
    mean = total / n
    var = max(total_sq / n - mean * mean, 0.0)
    disc = float(jnp.exp(-jnp.float32(r) * jnp.float32(t)))
    price = disc * mean
    stderr = disc * (var / n) ** 0.5
    return price, stderr


def example_args(n=None):
    """Example (params, key, offset) for lowering: shapes are what matter."""
    params = jnp.array([100.0, 105.0, 0.05, 0.2, 1.0, 150.0, 0.0, 0.0], jnp.float32)
    key = jnp.array([7, 42], jnp.uint32)
    offset = jnp.array([0], jnp.uint32)
    return params, key, offset
