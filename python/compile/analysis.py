"""Structural performance analysis of the L1 Pallas kernels.

``interpret=True`` wallclock on CPU is not a TPU proxy (see DESIGN.md
§Hardware-Adaptation), so the kernels are assessed *structurally*: VMEM
working-set per block, HBM traffic per path, ALU operation counts, and the
resulting VPU-roofline utilisation estimate for a TPU-class part. Run as

    python -m compile.analysis [--block 4096] [--steps 64]

and the same numbers back DESIGN.md §Perf / EXPERIMENTS.md §Perf.
"""

import argparse
from dataclasses import dataclass

# Reference TPU-class budgets (order-of-magnitude; v4-lite-ish core).
VMEM_BYTES = 16 * 1024 * 1024
VPU_OPS_PER_SEC = 2.0e12  # f32 vector ALU
HBM_BYTES_PER_SEC = 400e9

# ALU op counts per path-step (mirrors workload/option.rs flops_per_path).
THREEFRY_OPS = 90  # 20 rounds x (add, rot, xor) + key schedule
BOXMULLER_OPS = 40  # ln, sqrt, cos, scale
STEP_OPS = 12      # drift/vol update, exp, accumulate


@dataclass
class KernelProfile:
    payoff: str
    block: int
    steps: int
    live_vectors: int  # f32[block] values concurrently live in the kernel

    @property
    def vmem_bytes(self) -> int:
        """Working set: live f32 vectors + params/key/offset + partial out."""
        return self.live_vectors * self.block * 4 + 8 * 4 + 2 * 4 + 4 + 2 * 4

    @property
    def vmem_utilisation(self) -> float:
        return self.vmem_bytes / VMEM_BYTES

    @property
    def alu_ops_per_path(self) -> float:
        per_step = THREEFRY_OPS + BOXMULLER_OPS + STEP_OPS
        return self.steps * per_step + 25  # payoff + reduction epilogue

    @property
    def hbm_bytes_per_path(self) -> float:
        """O(1) HBM traffic per *block* (the in-kernel (Σ, Σ²) reduction);
        amortised per path it is the 8-byte partial over the block."""
        return 8.0 / self.block

    @property
    def arithmetic_intensity(self) -> float:
        """ALU ops per HBM byte — astronomically compute-bound by design."""
        return self.alu_ops_per_path / self.hbm_bytes_per_path

    @property
    def roofline_paths_per_sec(self) -> float:
        """Compute-roofline throughput estimate (VPU-bound)."""
        compute = VPU_OPS_PER_SEC / self.alu_ops_per_path
        memory = HBM_BYTES_PER_SEC / self.hbm_bytes_per_path
        return min(compute, memory)

    @property
    def compute_bound(self) -> bool:
        return (VPU_OPS_PER_SEC / self.alu_ops_per_path) < (
            HBM_BYTES_PER_SEC / self.hbm_bytes_per_path
        )


def profile(payoff: str, block: int = 4096, steps: int = 64) -> KernelProfile:
    """Live-vector counts read off the kernel bodies in kernels/mc.py."""
    live = {
        # ctr, z, u0/u1 (transient), st, payoff, payoff^2
        "european": 6,
        # ctr, z, log_s, acc, exp(log_s), payoff (+transients)
        "asian": 7,
        # ctr, z, log_s, alive, exp(log_s), payoff (+transients)
        "barrier": 7,
    }[payoff]
    eff_steps = 1 if payoff == "european" else steps
    return KernelProfile(payoff, block, eff_steps, live)


def report(block: int, steps: int) -> str:
    lines = [
        f"L1 kernel structural analysis (block={block}, steps={steps})",
        f"{'payoff':>10} {'VMEM':>10} {'%VMEM':>7} {'ops/path':>9} "
        f"{'AI (ops/B)':>11} {'roofline':>14} {'bound':>8}",
    ]
    for payoff in ("european", "asian", "barrier"):
        p = profile(payoff, block, steps)
        lines.append(
            f"{payoff:>10} {p.vmem_bytes/1024:>8.0f}KiB {p.vmem_utilisation*100:>6.2f}% "
            f"{p.alu_ops_per_path:>9.0f} {p.arithmetic_intensity:>11.2e} "
            f"{p.roofline_paths_per_sec:>11.2e}/s "
            f"{'compute' if p.compute_bound else 'memory':>8}"
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--block", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=64)
    args = ap.parse_args()
    print(report(args.block, args.steps))


if __name__ == "__main__":
    main()
